//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **strict vs work-conserving progressive filling** — the paper
//!    leaves the blocked-user case unspecified (see
//!    `sched::BestFitDrfh`); we quantify the fairness/utilization
//!    trade-off: strict keeps shares equalized (higher Jain index on
//!    dominant shares), work-conserving converts the stalled capacity
//!    into utilization.
//! 2. **Best-Fit vs First-Fit placement** — eq. (9)'s H heuristic vs
//!    naive lowest-index placement.
//! 3. **server-class aggregation in the exact allocator** — collapsing
//!    identical servers into classes vs solving the raw per-server LP.
//!
//! Run: `cargo bench --bench ablation`

use drfh::allocator::{self, FluidUser};
use drfh::cluster::{Cluster, ResVec, ServerClass};
use drfh::experiments::{runner, EvalSetup};
use drfh::sched::{BestFitDrfh, FirstFitDrfh, Scheduler};
use drfh::util::bench::{bench, header};
use drfh::util::{stats, Pcg32};
use std::time::Duration;

fn main() {
    // ---- 1+2. filling variant & placement heuristic --------------
    // three independent runs on clones of one setup, fanned out
    // through the parallel runtime with per-job options: the two
    // filling variants track user series (the Jain index needs them),
    // First-Fit keeps the untracked opts exactly as the old
    // sequential loop ran it
    let setup = EvalSetup::with_duration(42, 300, 30, 21_600.0);
    let opts = drfh::sim::SimOpts {
        track_user_series: true,
        ..setup.opts.clone()
    };
    let (cluster, trace) = (&setup.cluster, &setup.trace);
    let sim_job = |sched: fn() -> Box<dyn Scheduler>,
                   o: &drfh::sim::SimOpts| {
        let o = o.clone();
        let job: runner::Job<'_, drfh::sim::SimReport> =
            Box::new(move || drfh::sim::run(cluster.clone(), trace, sched(), o));
        job
    };
    let mut reports = runner::run_parallel(vec![
        sim_job(|| Box::new(BestFitDrfh::default()), &opts),
        sim_job(|| Box::new(BestFitDrfh::strict_filling()), &opts),
        sim_job(|| Box::new(FirstFitDrfh::default()), &setup.opts),
    ]);
    let ff = reports.pop().expect("first-fit report");
    let strict = reports.pop().expect("strict report");
    let wc = reports.pop().expect("work-conserving report");
    let jain = |r: &drfh::sim::SimReport| {
        // Jain index over mean dominant shares of users with work
        let shares: Vec<f64> = r
            .user_dom_share
            .iter()
            .map(|ts| stats::mean(&ts.v))
            .filter(|&s| s > 1e-9)
            .collect();
        stats::jain_index(&shares)
    };
    println!("== ablation 1: progressive filling variant ==");
    println!(
        "{:<18} {:>10} {:>10} {:>12} {:>12}",
        "variant", "CPU util", "mem util", "tasks done", "Jain(shares)"
    );
    for (name, r) in [("work-conserving", &wc), ("strict", &strict)] {
        println!(
            "{:<18} {:>9.1}% {:>9.1}% {:>12} {:>12.4}",
            name,
            r.avg_cpu_util * 100.0,
            r.avg_mem_util * 100.0,
            r.tasks_completed,
            jain(r)
        );
    }
    assert!(
        wc.tasks_completed >= strict.tasks_completed,
        "work conservation must not complete less work"
    );

    // ---- 2. Best-Fit vs First-Fit --------------------------------
    println!("\n== ablation 2: placement heuristic ==");
    println!(
        "best-fit: cpu {:.1}% tasks {};  first-fit: cpu {:.1}% tasks {}",
        wc.avg_cpu_util * 100.0,
        wc.tasks_completed,
        ff.avg_cpu_util * 100.0,
        ff.tasks_completed
    );

    // ---- 3. class aggregation in the exact allocator -------------
    header("ablation 3: exact DRFH — class-aggregated vs raw-server LP");
    let mut rng = Pcg32::seeded(3);
    // raw per-server LP is O((n·k)³)-ish in the dense simplex — keep k
    // modest so the ablation finishes in seconds; the point (identical
    // optimum, orders-of-magnitude cost gap) is scale-independent
    let cluster = Cluster::google_sample(60, &mut rng);
    let users: Vec<FluidUser> = (0..10)
        .map(|_| {
            FluidUser::unweighted(ResVec::cpu_mem(
                rng.uniform(0.02, 0.5),
                rng.uniform(0.02, 0.5),
            ))
        })
        .collect();
    let agg = bench("aggregated classes (<=10)", Duration::from_millis(800), 200, || {
        allocator::solve(&cluster, &users).g[0]
    });
    // raw: one class per server (what the naive formulation would do)
    let raw_classes: Vec<ServerClass> = cluster
        .servers
        .iter()
        .map(|s| ServerClass { capacity: s.capacity, count: 1 })
        .collect();
    let total = cluster.total_capacity();
    let raw = bench("raw per-server classes (60)", Duration::from_secs(3), 3, || {
        allocator::drfh::solve_classes(&raw_classes, &total, &users).g[0]
    });
    // same optimum, very different cost
    let g_agg = allocator::solve(&cluster, &users).g[0];
    let g_raw = allocator::drfh::solve_classes(&raw_classes, &total, &users).g[0];
    assert!(
        (g_agg - g_raw).abs() < 1e-6,
        "aggregation changed the optimum: {g_agg} vs {g_raw}"
    );
    println!(
        "speedup from class aggregation: {:.0}x (same optimum g = {:.6})",
        raw.p50.as_secs_f64() / agg.p50.as_secs_f64(),
        g_agg
    );
}
