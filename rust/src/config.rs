//! TOML experiment configuration: the launcher's input format.
//!
//! ```toml
//! seed = 42
//! [cluster]
//! servers = 2000
//! [workload]
//! users = 100
//! duration = 86400.0
//! jobs_per_user = 20.0
//! [sim]
//! horizon = 86400.0
//! sample_dt = 60.0
//! track_user_series = false
//! queue = "wheel"          # wheel | auto (trace-tuned wheel) | heap (naive parity reference)
//! metrics = "full"         # full | streaming (bounded memory)
//! share_sketch = 2048      # optional: per-user share-sketch point budget (0 = exact)
//! shards = "auto"          # 1 (sequential, default) | N | "auto" (per-core data-plane shards)
//! audit = false            # wave-boundary invariant auditor (sim::audit; also DRFH_AUDIT=1)
//! [scheduler]
//! policy = "bestfit"       # bestfit | firstfit | slots | bestfit-xla
//! slots_per_max = 14       # slots policy only
//! ```
//!
//! Parsed with the in-tree TOML-subset parser (`util::toml_lite`; the
//! `toml` crate is unavailable offline).

use crate::cluster::Cluster;
use crate::sched::{BestFitDrfh, FirstFitDrfh, Scheduler, SlotsScheduler};
use crate::sim::{MetricsMode, QueueKind, ShardCount, SimOpts};
use crate::util::toml_lite;
use crate::util::Pcg32;
use crate::workload::{GoogleLikeConfig, TraceGenerator};
use crate::util::error::{anyhow, bail, Context, Result};

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of servers sampled from the Google Table I distribution.
    pub servers: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { servers: 2000 }
    }
}

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// bestfit | firstfit | slots | bestfit-xla
    pub policy: String,
    /// Slots per maximum server (slots policy only).
    pub slots_per_max: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { policy: "bestfit".into(), slots_per_max: 14 }
    }
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub horizon: f64,
    pub sample_dt: f64,
    pub track_user_series: bool,
    /// Event queue: "wheel" (default) | "auto" (wheel with geometry
    /// tuned from the trace's duration distribution) | "heap" (naive
    /// parity reference).
    pub queue: String,
    /// Metrics retention: "full" (default) | "streaming" (bounded
    /// memory for trace-scale runs).
    pub metrics: String,
    /// Per-user dominant-share sketch budget (points; 0 = exact
    /// retention). Unset = sketches off.
    pub share_sketch: Option<usize>,
    /// Data-plane shards: "1" (sequential, default) | "N" | "auto"
    /// (one shard per core). Reports are bit-identical across all
    /// choices; this is purely a wall-clock lever.
    pub shards: String,
    /// Wave-boundary invariant auditing (`crate::sim::audit`):
    /// decision-neutral, so reports stay bit-identical; panics with a
    /// structured dump on the first violated invariant.
    pub audit: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            horizon: 86_400.0,
            sample_dt: 60.0,
            track_user_series: false,
            queue: "wheel".into(),
            metrics: "full".into(),
            share_sketch: None,
            shards: "1".into(),
            audit: false,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    pub seed: u64,
    pub cluster: ClusterConfig,
    pub workload: GoogleLikeConfig,
    pub sim: SimConfig,
    pub scheduler: SchedulerConfig,
}

impl ExperimentConfig {
    /// Parse from a TOML string (unset keys keep their defaults).
    pub fn from_toml(s: &str) -> Result<Self> {
        let doc = toml_lite::parse(s)
            .map_err(|e| anyhow!("parsing experiment config: {e}"))?;
        let mut cfg = ExperimentConfig::default();
        if let Some(seed) = doc.get("", "seed").and_then(|v| v.as_u64()) {
            cfg.seed = seed;
        }
        if let Some(v) = doc.get_usize("cluster", "servers") {
            cfg.cluster.servers = v;
        }
        let w = &mut cfg.workload;
        if let Some(v) = doc.get_usize("workload", "users") {
            w.users = v;
        }
        if let Some(v) = doc.get_f64("workload", "duration") {
            w.duration = v;
        }
        if let Some(v) = doc.get_f64("workload", "jobs_per_user") {
            w.jobs_per_user = v;
        }
        if let Some(v) = doc.get_usize("workload", "max_tasks_per_job") {
            w.max_tasks_per_job = v;
        }
        if let Some(v) = doc.get_f64("workload", "job_size_zipf_s") {
            w.job_size_zipf_s = v;
        }
        if let Some(v) = doc.get_f64("workload", "dur_lo") {
            w.dur_lo = v;
        }
        if let Some(v) = doc.get_f64("workload", "dur_hi") {
            w.dur_hi = v;
        }
        if let Some(v) = doc.get_f64("workload", "dur_alpha") {
            w.dur_alpha = v;
        }
        if let Some(v) = doc.get_f64("sim", "horizon") {
            cfg.sim.horizon = v;
        }
        if let Some(v) = doc.get_f64("sim", "sample_dt") {
            cfg.sim.sample_dt = v;
        }
        if let Some(v) = doc.get_bool("sim", "track_user_series") {
            cfg.sim.track_user_series = v;
        }
        if let Some(v) = doc.get_str("sim", "queue") {
            cfg.sim.queue = v.to_string();
        }
        if let Some(v) = doc.get_str("sim", "metrics") {
            cfg.sim.metrics = v.to_string();
        }
        if let Some(v) = doc.get_usize("sim", "share_sketch") {
            cfg.sim.share_sketch = Some(v);
        }
        if let Some(v) = doc.get_bool("sim", "audit") {
            cfg.sim.audit = v;
        }
        // shards accepts both a bare integer and the string "auto"
        if let Some(v) = doc.get_usize("sim", "shards") {
            cfg.sim.shards = v.to_string();
        } else if let Some(v) = doc.get_str("sim", "shards") {
            cfg.sim.shards = v.to_string();
        }
        if let Some(v) = doc.get_str("scheduler", "policy") {
            cfg.scheduler.policy = v.to_string();
        }
        if let Some(v) = doc.get_usize("scheduler", "slots_per_max") {
            cfg.scheduler.slots_per_max = v;
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml(&s)
    }

    /// Sample the cluster.
    pub fn build_cluster(&self) -> Cluster {
        let mut rng = Pcg32::new(self.seed, 0xc1u64);
        Cluster::google_sample(self.cluster.servers, &mut rng)
    }

    /// Generate the trace.
    pub fn build_trace(&self) -> crate::workload::Trace {
        TraceGenerator::new(self.workload.clone()).generate(self.seed)
    }

    /// Instantiate the scheduler policy.
    pub fn build_scheduler(
        &self,
        cluster: &Cluster,
    ) -> Result<Box<dyn Scheduler>> {
        Ok(match self.scheduler.policy.as_str() {
            "bestfit" => Box::new(BestFitDrfh::default()),
            "firstfit" => Box::new(FirstFitDrfh::default()),
            "slots" => Box::new(SlotsScheduler::new(
                cluster,
                self.scheduler.slots_per_max,
            )),
            "bestfit-xla" => {
                let rt = std::sync::Arc::new(
                    crate::runtime::XlaRuntime::load_default()?,
                );
                Box::new(crate::sched::XlaBestFit::new(rt))
            }
            other => bail!("unknown scheduler policy '{other}'"),
        })
    }

    /// Simulation options (validating the queue / metrics choices).
    pub fn sim_opts(&self) -> Result<SimOpts> {
        let queue = match self.sim.queue.as_str() {
            "wheel" => QueueKind::Wheel,
            "auto" => QueueKind::Auto,
            "heap" => QueueKind::Heap,
            other => {
                bail!("unknown sim queue '{other}' (wheel | auto | heap)")
            }
        };
        let metrics = match self.sim.metrics.as_str() {
            "full" => MetricsMode::Full,
            "streaming" => MetricsMode::streaming(),
            other => {
                bail!("unknown sim metrics '{other}' (full | streaming)")
            }
        };
        let shards = match self.sim.shards.as_str() {
            "auto" => ShardCount::Auto,
            s => match s.parse::<usize>() {
                Ok(n) if n >= 1 => ShardCount::Fixed(n),
                _ => bail!(
                    "unknown sim shards '{s}' (\"auto\" | N >= 1)"
                ),
            },
        };
        Ok(SimOpts {
            horizon: self.sim.horizon,
            sample_dt: self.sim.sample_dt,
            track_user_series: self.sim.track_user_series,
            queue,
            metrics,
            share_sketch: self.sim.share_sketch,
            shards,
            audit: self.sim.audit,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse_from_empty() {
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.cluster.servers, 2000);
        assert_eq!(c.scheduler.policy, "bestfit");
        assert_eq!(c.scheduler.slots_per_max, 14);
    }

    #[test]
    fn full_toml_roundtrip() {
        let toml_src = r#"
            seed = 7
            [cluster]
            servers = 100
            [workload]
            users = 3
            duration = 2000.0
            [sim]
            horizon = 2000.0
            sample_dt = 10.0
            track_user_series = true
            [scheduler]
            policy = "slots"
            slots_per_max = 16
        "#;
        let c = ExperimentConfig::from_toml(toml_src).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.cluster.servers, 100);
        assert_eq!(c.workload.users, 3);
        assert_eq!(c.scheduler.slots_per_max, 16);
        assert!(c.sim.track_user_series);
        let cluster = c.build_cluster();
        assert_eq!(cluster.len(), 100);
        let sched = c.build_scheduler(&cluster).unwrap();
        assert_eq!(sched.name(), "slots");
    }

    #[test]
    fn queue_and_metrics_parse_and_validate() {
        let c = ExperimentConfig::from_toml("").unwrap();
        let opts = c.sim_opts().unwrap();
        assert_eq!(opts.queue, QueueKind::Wheel);
        assert_eq!(opts.metrics, MetricsMode::Full);

        let c = ExperimentConfig::from_toml(
            "[sim]\nqueue = 'heap'\nmetrics = 'streaming'",
        )
        .unwrap();
        let opts = c.sim_opts().unwrap();
        assert_eq!(opts.queue, QueueKind::Heap);
        assert!(matches!(opts.metrics, MetricsMode::Streaming { .. }));

        let c = ExperimentConfig::from_toml(
            "[sim]\nqueue = 'auto'\nshare_sketch = 128",
        )
        .unwrap();
        let opts = c.sim_opts().unwrap();
        assert_eq!(opts.queue, QueueKind::Auto);
        assert_eq!(opts.share_sketch, Some(128));

        let c =
            ExperimentConfig::from_toml("[sim]\nqueue = 'nope'").unwrap();
        assert!(c.sim_opts().is_err());
        let c =
            ExperimentConfig::from_toml("[sim]\nmetrics = 'nope'").unwrap();
        assert!(c.sim_opts().is_err());
    }

    #[test]
    fn shards_parse_and_validate() {
        // default: sequential
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.sim_opts().unwrap().shards, ShardCount::Fixed(1));
        // bare integer
        let c = ExperimentConfig::from_toml("[sim]\nshards = 8").unwrap();
        assert_eq!(c.sim_opts().unwrap().shards, ShardCount::Fixed(8));
        // quoted integer and "auto"
        let c = ExperimentConfig::from_toml("[sim]\nshards = '4'").unwrap();
        assert_eq!(c.sim_opts().unwrap().shards, ShardCount::Fixed(4));
        let c =
            ExperimentConfig::from_toml("[sim]\nshards = 'auto'").unwrap();
        assert_eq!(c.sim_opts().unwrap().shards, ShardCount::Auto);
        // rejects zero and junk
        let c = ExperimentConfig::from_toml("[sim]\nshards = 0").unwrap();
        assert!(c.sim_opts().is_err());
        let c =
            ExperimentConfig::from_toml("[sim]\nshards = 'many'").unwrap();
        assert!(c.sim_opts().is_err());
    }

    #[test]
    fn audit_parses_and_defaults_off() {
        let c = ExperimentConfig::from_toml("").unwrap();
        assert!(!c.sim_opts().unwrap().audit);
        let c =
            ExperimentConfig::from_toml("[sim]\naudit = true").unwrap();
        assert!(c.sim_opts().unwrap().audit);
    }

    #[test]
    fn bad_policy_rejected() {
        let c = ExperimentConfig::from_toml("[scheduler]\npolicy = 'nope'")
            .unwrap();
        let cluster = c.build_cluster();
        assert!(c.build_scheduler(&cluster).is_err());
    }

    #[test]
    fn deterministic_cluster_and_trace() {
        let c = ExperimentConfig::from_toml("seed = 5").unwrap();
        let a = c.build_cluster();
        let b = c.build_cluster();
        for (x, y) in a.servers.iter().zip(&b.servers) {
            assert_eq!(x.capacity, y.capacity);
        }
        assert_eq!(c.build_trace().total_tasks(), c.build_trace().total_tasks());
    }
}
