//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from the scheduling hot
//! path. Python never runs at request time — the artifacts directory is
//! the only interface between the layers.
//!
//! Interchange is HLO *text* (see aot.py and /opt/xla-example/README.md:
//! jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1's
//! proto path rejects; the text parser reassigns ids).

pub mod picker;
pub mod xla;

use crate::util::error::{anyhow, Context, Result};
use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

/// One AOT-compiled `sched_step` shape variant.
#[derive(Clone, Debug)]
pub struct StepVariant {
    pub n: usize,
    pub k: usize,
    pub m: usize,
    pub file: String,
}

/// One AOT-compiled `sched_loop` shape variant.
#[derive(Clone, Debug)]
pub struct LoopVariant {
    pub n: usize,
    pub k: usize,
    pub m: usize,
    pub steps: usize,
    pub file: String,
}

/// Parsed artifacts/manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub step: Vec<StepVariant>,
    pub loops: Vec<LoopVariant>,
}

impl Manifest {
    /// Parse the manifest JSON emitted by `python/compile/aot.py`.
    pub fn parse(data: &str) -> Result<Self> {
        let v = json::parse(data).map_err(|e| anyhow!("manifest: {e}"))?;
        let get = |entry: &Json, key: &str| -> Result<usize> {
            entry
                .get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest entry missing '{key}'"))
        };
        let file = |entry: &Json| -> Result<String> {
            Ok(entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest entry missing 'file'"))?
                .to_string())
        };
        let step = v
            .get("step")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'step'"))?
            .iter()
            .map(|e| {
                Ok(StepVariant {
                    n: get(e, "n")?,
                    k: get(e, "k")?,
                    m: get(e, "m")?,
                    file: file(e)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let loops = v
            .get("loop")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'loop'"))?
            .iter()
            .map(|e| {
                Ok(LoopVariant {
                    n: get(e, "n")?,
                    k: get(e, "k")?,
                    m: get(e, "m")?,
                    steps: get(e, "steps")?,
                    file: file(e)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { step, loops })
    }
}

/// Result of a batched `sched_loop` invocation.
#[derive(Clone, Debug)]
pub struct LoopOutcome {
    /// (user, server) decisions in order; -1/-1 entries are no-ops.
    pub decisions: Vec<(i32, i32)>,
    /// Updated availability matrix, row-major [k, m] (unpadded view).
    pub avail: Vec<f32>,
    /// Updated global dominant shares (unpadded).
    pub share: Vec<f32>,
    /// Updated pending task counts (unpadded).
    pub pending: Vec<i32>,
}

/// Default artifacts directory, overridable with `DRFH_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("DRFH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True when AOT artifacts are present (used by tests to skip
/// gracefully when `make artifacts` has not run).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// True when a real PJRT backend is linked in (false under the
/// `runtime::xla` stub). XLA-dependent tests and the launcher check
/// this *and* [`artifacts_available`] before exercising the runtime,
/// so a stub build with artifacts on disk skips instead of panicking.
pub fn backend_available() -> bool {
    xla::AVAILABLE
}

struct CompiledStep {
    v: StepVariant,
    exe: xla::PjRtLoadedExecutable,
}

struct CompiledLoop {
    v: LoopVariant,
    exe: xla::PjRtLoadedExecutable,
}

/// The XLA-backed scheduling runtime: a PJRT CPU client plus one
/// compiled executable per AOT shape variant.
pub struct XlaRuntime {
    _client: xla::PjRtClient,
    steps: Vec<CompiledStep>,
    loops: Vec<CompiledLoop>,
}

impl XlaRuntime {
    /// Load every artifact listed in `<dir>/manifest.json` and compile
    /// it on a fresh PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let data = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&data)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;

        let mut steps = Vec::new();
        for v in manifest.step {
            let exe = compile(&client, &dir.join(&v.file))?;
            steps.push(CompiledStep { v, exe });
        }
        let mut loops = Vec::new();
        for v in manifest.loops {
            let exe = compile(&client, &dir.join(&v.file))?;
            loops.push(CompiledLoop { v, exe });
        }
        // smallest-first so variant selection picks the tightest fit
        steps.sort_by_key(|s| (s.v.n * s.v.k, s.v.n, s.v.k));
        loops.sort_by_key(|l| (l.v.n * l.v.k, l.v.n, l.v.k));
        Ok(XlaRuntime { _client: client, steps, loops })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir())
    }

    /// Shape variants available for `sched_step`, (n, k, m).
    pub fn step_variants(&self) -> Vec<(usize, usize, usize)> {
        self.steps.iter().map(|s| (s.v.n, s.v.k, s.v.m)).collect()
    }

    /// One scheduling decision via the AOT `sched_step` graph.
    ///
    /// Inputs are the *live* sizes (n users, k servers, m resources);
    /// they are padded into the smallest compiled variant. Returns
    /// (user, server), -1/-1 when no placement is possible.
    #[allow(clippy::too_many_arguments)]
    pub fn sched_step(
        &self,
        avail: &[f32],
        demand: &[f32],
        share: &[f32],
        weight: &[f32],
        active: &[i32],
        n: usize,
        k: usize,
        m: usize,
    ) -> Result<(i32, i32)> {
        debug_assert_eq!(avail.len(), k * m);
        debug_assert_eq!(demand.len(), n * m);
        let cs = self
            .steps
            .iter()
            .find(|s| s.v.n >= n && s.v.k >= k && s.v.m == m)
            .ok_or_else(|| {
                anyhow!("no sched_step variant fits n={n} k={k} m={m}")
            })?;
        let (vn, vk) = (cs.v.n, cs.v.k);

        let avail_p = pad_matrix(avail, k, vk, m, 0.0);
        let demand_p = pad_matrix(demand, n, vn, m, 0.0);
        let share_p = pad_vec(share, vn, 0.0f32);
        let weight_p = pad_vec(weight, vn, 1.0f32);
        let active_p = pad_vec(active, vn, 0i32);

        let lits = [
            lit_f32(&avail_p, &[vk as i64, m as i64])?,
            lit_f32(&demand_p, &[vn as i64, m as i64])?,
            lit_f32(&share_p, &[vn as i64])?,
            lit_f32(&weight_p, &[vn as i64])?,
            lit_i32(&active_p, &[vn as i64])?,
        ];
        let out = cs
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute sched_step: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (u_lit, s_lit) =
            out.to_tuple2().map_err(|e| anyhow!("tuple2: {e:?}"))?;
        let u = u_lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?[0];
        let s = s_lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok((u, s))
    }

    /// Batched decisions via the AOT `sched_loop` graph: up to the
    /// variant's `steps` placements in a single PJRT call, with state
    /// updates applied in-graph.
    #[allow(clippy::too_many_arguments)]
    pub fn sched_loop(
        &self,
        avail: &[f32],
        demand: &[f32],
        share: &[f32],
        weight: &[f32],
        pending: &[i32],
        n: usize,
        k: usize,
        m: usize,
    ) -> Result<LoopOutcome> {
        let cl = self
            .loops
            .iter()
            .find(|l| l.v.n >= n && l.v.k >= k && l.v.m == m)
            .ok_or_else(|| {
                anyhow!("no sched_loop variant fits n={n} k={k} m={m}")
            })?;
        let (vn, vk) = (cl.v.n, cl.v.k);

        let avail_p = pad_matrix(avail, k, vk, m, 0.0);
        let demand_p = pad_matrix(demand, n, vn, m, 0.0);
        let share_p = pad_vec(share, vn, 0.0f32);
        let weight_p = pad_vec(weight, vn, 1.0f32);
        let pending_p = pad_vec(pending, vn, 0i32);

        let lits = [
            lit_f32(&avail_p, &[vk as i64, m as i64])?,
            lit_f32(&demand_p, &[vn as i64, m as i64])?,
            lit_f32(&share_p, &[vn as i64])?,
            lit_f32(&weight_p, &[vn as i64])?,
            lit_i32(&pending_p, &[vn as i64])?,
        ];
        let out = cl
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute sched_loop: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (dec, av, sh, pe) =
            out.to_tuple4().map_err(|e| anyhow!("tuple4: {e:?}"))?;
        let dec = dec.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?;
        let av = av.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let sh = sh.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let pe = pe.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?;

        let decisions =
            dec.chunks(2).map(|c| (c[0], c[1])).collect::<Vec<_>>();
        // strip padding back out
        let mut avail_out = Vec::with_capacity(k * m);
        for r in 0..k {
            avail_out.extend_from_slice(&av[r * m..r * m + m]);
        }
        Ok(LoopOutcome {
            decisions,
            avail: avail_out,
            share: sh[..n].to_vec(),
            pending: pe[..n].to_vec(),
        })
    }

    /// Max batch size of the loop variant that serves (n, k, m).
    pub fn loop_steps(&self, n: usize, k: usize, m: usize) -> Option<usize> {
        self.loops
            .iter()
            .find(|l| l.v.n >= n && l.v.k >= k && l.v.m == m)
            .map(|l| l.v.steps)
    }
}

fn compile(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape f32: {e:?}"))
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape i32: {e:?}"))
}

/// Pad a row-major [rows, m] matrix to [rows_to, m] with `fill`.
fn pad_matrix(
    data: &[f32],
    rows: usize,
    rows_to: usize,
    m: usize,
    fill: f32,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows_to * m);
    out.extend_from_slice(&data[..rows * m]);
    out.resize(rows_to * m, fill);
    out
}

fn pad_vec<T: Copy>(data: &[T], to: usize, fill: T) -> Vec<T> {
    let mut out = data.to_vec();
    out.resize(to, fill);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_helpers() {
        let m = pad_matrix(&[1.0, 2.0, 3.0, 4.0], 2, 4, 2, 0.0);
        assert_eq!(m, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        let v = pad_vec(&[1i32, 2], 4, 9);
        assert_eq!(v, vec![1, 2, 9, 9]);
    }

    #[test]
    fn artifacts_dir_default() {
        if std::env::var_os("DRFH_ARTIFACTS").is_none() {
            assert!(artifacts_dir().ends_with("artifacts"));
        }
    }
}
