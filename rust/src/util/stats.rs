//! Small statistics toolkit shared by metrics and the experiment
//! harness: means, percentiles, empirical CDFs, Jain's fairness index.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0 for fewer than 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Linear-interpolated percentile, p in [0, 100]. NaN-tolerant: sorts
/// with `total_cmp` instead of panicking mid-sort (NaNs group at the
/// extremes by sign bit — positive NaNs last, negative NaNs first —
/// so a NaN-bearing input yields NaN percentiles at the affected end
/// rather than a panic).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Empirical CDF evaluated at `points` many equally spaced quantiles;
/// returns (value, fraction <= value) pairs suitable for plotting.
pub fn cdf_points(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() {
        return vec![];
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp); // NaN-tolerant, like `percentile`
    let n = v.len();
    (0..points)
        .map(|i| {
            let q = (i as f64 + 1.0) / points as f64;
            let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
            (v[idx], q)
        })
        .collect()
}

/// Jain's fairness index: (Σx)² / (n·Σx²); 1 = perfectly fair.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        1.0
    } else {
        s * s / (xs.len() as f64 * s2)
    }
}

/// Histogram with `bins` equal-width bins over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if hi <= lo || bins == 0 {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            // `(x - lo) / w` can round up to exactly `bins` for x just
            // below hi (e.g. lo 0, hi 3.5, bins 5, x = 3.5 - 1 ulp):
            // clamp the index instead of walking off the array
            h[(((x - lo) / w) as usize).min(bins - 1)] += 1;
        } else if (x - hi).abs() < 1e-12 {
            h[bins - 1] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let cdf = cdf_points(&xs, 10);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(cdf.last().unwrap().0, 5.0);
    }

    #[test]
    fn jain_extremes() {
        assert!((jain_index(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        let skew = jain_index(&[1.0, 0.0, 0.0]);
        assert!((skew - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9, 1.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }

    /// Regression: x one ulp below hi used to compute bin index ==
    /// bins and panic on `h[bins]` (float division rounds up); the
    /// index is clamped into the last bin. Both literals are exact
    /// f64 values verified to trigger the rounding.
    #[test]
    fn histogram_clamps_rounded_up_bin() {
        // (x - lo) / w == 5.0 exactly for x = nextafter(3.5, -inf)
        let h = histogram(&[3.4999999999999996], 0.0, 3.5, 5);
        assert_eq!(h.iter().sum::<usize>(), 1);
        assert_eq!(h[4], 1);
        // and == 10.0 for x = nextafter(7.0, -inf)
        let h = histogram(&[6.999999999999999], 0.0, 7.0, 10);
        assert_eq!(h.iter().sum::<usize>(), 1);
        assert_eq!(h[9], 1);
    }

    /// Regression: NaN samples used to panic `partial_cmp().unwrap()`
    /// inside the sort; `total_cmp` groups them at the sign-matching
    /// extreme instead. Both NaN signs are covered — runtime NaNs
    /// (e.g. `0.0/0.0` on x86-64) often carry the sign bit.
    #[test]
    fn percentile_and_cdf_tolerate_nan() {
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 100.0).is_nan()); // +NaN ranks last
        let cdf = cdf_points(&xs, 4);
        assert_eq!(cdf.len(), 4);
        assert_eq!(cdf[0].0, 1.0); // finite values keep their order
        // negative NaN ranks first: the low end goes NaN, the high
        // end stays finite — and still no panic
        let neg = [3.0, -f64::NAN, 1.0, 2.0];
        assert!(percentile(&neg, 0.0).is_nan());
        assert_eq!(percentile(&neg, 100.0), 3.0);
        assert_eq!(cdf_points(&neg, 4).len(), 4);
    }
}
