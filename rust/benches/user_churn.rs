//! §Perf + determinism harness for the churn layer: the class-keyed
//! Best-Fit configuration at 10⁶ users / ~10 demand classes under a
//! churn-rate sweep, on the wheel + streaming data plane.
//!
//! Measured per cell: wall time, applied joins/leaves, abandoned
//! tasks, and end-to-end task throughput. Alongside the sweep the
//! bench enforces the two replay guarantees cheaply (the bit-exact
//! proofs live in `tests/engine_parity.rs`):
//!
//! * `ChurnPlan::none()` parity — the churn-free run matches itself
//!   at 1 shard and at the core count, with every churn counter zero;
//! * seeded replay — the same plan + seed reproduces goodput and
//!   abandoned-work floats bit-for-bit, sharded or not.
//!
//! Results go to `BENCH_churn.json` at the repo root (override with
//! `BENCH_OUT=/path.json`); CI runs the small-scale smoke via
//! `CHURN_SMOKE=1`.
//!
//! Run: `cargo bench --bench user_churn`

use drfh::cluster::Cluster;
use drfh::experiments::user_scale::{classed_trace, DEFAULT_CLASSES};
use drfh::metrics::MetricsMode;
use drfh::sched::BestFitDrfh;
use drfh::sim::{run, ChurnPlan, ShardCount, SimOpts, SimReport};
use drfh::util::bench::{bench_n, header, write_suite_json, BenchResult};
use drfh::util::json::Json;
use drfh::util::Pcg32;
use drfh::workload::{generate_churn, ChurnGenConfig, Trace};
use std::collections::BTreeMap;

struct Case {
    bench: BenchResult,
    report: SimReport,
}

fn run_case(
    name: &str,
    setup: &(Cluster, Trace, SimOpts),
    plan: &ChurnPlan,
    shards: usize,
) -> Case {
    let (cluster, trace, opts) = setup;
    let mut report = None;
    let bench = bench_n(name, 1, || {
        let opts = SimOpts {
            metrics: MetricsMode::streaming(),
            shards: ShardCount::Fixed(shards),
            churn: plan.clone(),
            ..opts.clone()
        };
        let rep = run(
            cluster.clone(),
            trace,
            Box::new(BestFitDrfh::default()),
            opts,
        );
        let placed = rep.tasks_placed;
        report = Some(rep);
        placed
    });
    Case { bench, report: report.expect("bench ran at least once") }
}

fn tasks_per_sec(c: &Case) -> f64 {
    c.report.tasks_completed as f64 / c.bench.mean.as_secs_f64().max(1e-12)
}

fn main() {
    let smoke = std::env::var_os("CHURN_SMOKE").is_some();
    let (servers, users, total_tasks, duration): (usize, usize, usize, f64) =
        if smoke {
            (200, 5_000, 8_000, 3_600.0)
        } else {
            (2_000, 1_000_000, 200_000, 14_400.0)
        };
    let classes = DEFAULT_CLASSES;
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    println!(
        "user_churn: k={servers} n={users} classes={classes} \
         ~{total_tasks} tasks over {duration:.0}s ({hw} cores){}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut rng = Pcg32::new(2026, 0xc1);
    let cluster = Cluster::google_sample(servers, &mut rng);
    let trace = classed_trace(users, classes, total_tasks, duration, 2026);
    let opts = SimOpts {
        horizon: duration,
        sample_dt: (duration / 200.0).max(10.0),
        ..SimOpts::default()
    };
    let setup = (cluster, trace, opts);

    // ---- replay guards first: none-plan parity and seeded replay
    header("user_churn: replay guards");
    let none = ChurnPlan::none();
    let baseline = run_case("none-s1", &setup, &none, 1);
    let baseline_sharded = run_case("none-shw", &setup, &none, hw);
    assert_eq!(
        baseline.report.tasks_placed, baseline_sharded.report.tasks_placed,
        "ChurnPlan::none() parity: placement counts diverged across shards"
    );
    assert_eq!(
        baseline.report.job_stats, baseline_sharded.report.job_stats,
        "ChurnPlan::none() parity: job stats diverged across shards"
    );
    assert_eq!(baseline.report.user_joins, 0);
    assert_eq!(baseline.report.user_leaves, 0);
    assert_eq!(baseline.report.tasks_abandoned, 0);
    assert_eq!(baseline.report.abandoned_s, 0.0);

    let guard_cfg = ChurnGenConfig {
        leave_rate: if smoke { 2e-4 } else { 2e-5 },
        absent_frac: 0.2,
        flash_at: Some(duration / 3.0),
        flash_fraction: 0.25,
        flash_hold: duration / 8.0,
        ..ChurnGenConfig::default()
    };
    let guard_plan =
        generate_churn(&guard_cfg, users, duration, 2026);
    let replay_a = run_case("replay-a", &setup, &guard_plan, 1);
    let replay_b = run_case("replay-b", &setup, &guard_plan, 1);
    let replay_s = run_case("replay-shw", &setup, &guard_plan, hw);
    for (label, r) in
        [("same-seed rerun", &replay_b), ("sharded rerun", &replay_s)]
    {
        assert_eq!(
            replay_a.report.goodput_s.to_bits(),
            r.report.goodput_s.to_bits(),
            "{label}: goodput not bit-identical"
        );
        assert_eq!(
            replay_a.report.abandoned_s.to_bits(),
            r.report.abandoned_s.to_bits(),
            "{label}: abandoned work not bit-identical"
        );
        assert_eq!(
            (
                replay_a.report.tasks_placed,
                replay_a.report.user_joins,
                replay_a.report.user_leaves,
                replay_a.report.tasks_abandoned,
            ),
            (
                r.report.tasks_placed,
                r.report.user_joins,
                r.report.user_leaves,
                r.report.tasks_abandoned,
            ),
            "{label}: counters diverged"
        );
    }
    assert!(
        replay_a.report.user_leaves > 0,
        "guard plan churned nobody — the sweep below would be vacuous"
    );
    println!(
        "guards ok: none-plan parity at S=1/{hw}, seeded replay \
         bit-identical ({} joins, {} leaves)",
        replay_a.report.user_joins, replay_a.report.user_leaves
    );

    // ---- the sweep: churn (leave) rate at fixed population
    let leave_rates: &[f64] =
        if smoke { &[1e-4, 4e-4] } else { &[1e-6, 1e-5, 1e-4] };
    header("user_churn: churn-rate sweep (Best-Fit classed, sharded)");
    println!(
        "{:<16} {:>8} {:>8} {:>8} {:>10} {:>11} {:>11}",
        "case", "events", "joins", "leaves", "abandoned", "tasks done",
        "tasks/s"
    );
    let mut results = vec![
        baseline.bench,
        baseline_sharded.bench,
        replay_a.bench,
        replay_b.bench,
        replay_s.bench,
    ];
    let mut rows: Vec<Json> = Vec::new();
    for &rate in leave_rates {
        let cfg = ChurnGenConfig {
            leave_rate: rate,
            absent_frac: 0.1,
            ..ChurnGenConfig::default()
        };
        let plan = generate_churn(&cfg, users, duration, 2026);
        let name = format!("churn-{rate:.0e}");
        let case = run_case(&name, &setup, &plan, hw);
        let r = &case.report;
        println!(
            "{:<16} {:>8} {:>8} {:>8} {:>10} {:>11} {:>11.0}",
            name,
            plan.events.len(),
            r.user_joins,
            r.user_leaves,
            r.tasks_abandoned,
            r.tasks_completed,
            tasks_per_sec(&case),
        );
        let mut row = BTreeMap::new();
        row.insert("leave_rate".to_string(), Json::Num(rate));
        row.insert(
            "plan_events".to_string(),
            Json::Num(plan.events.len() as f64),
        );
        row.insert("joins".to_string(), Json::Num(r.user_joins as f64));
        row.insert("leaves".to_string(), Json::Num(r.user_leaves as f64));
        row.insert(
            "tasks_abandoned".to_string(),
            Json::Num(r.tasks_abandoned as f64),
        );
        row.insert(
            "tasks_per_sec".to_string(),
            Json::Num(tasks_per_sec(&case)),
        );
        rows.push(Json::Obj(row));
        results.push(case.bench);
    }

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_churn.json")
            .to_string()
    });
    let meta = [
        ("servers", Json::Num(servers as f64)),
        ("users", Json::Num(users as f64)),
        ("classes", Json::Num(classes as f64)),
        ("tasks_offered_approx", Json::Num(total_tasks as f64)),
        ("horizon_s", Json::Num(duration)),
        ("smoke", Json::Bool(smoke)),
        ("cores", Json::Num(hw as f64)),
        (
            "guard_joins",
            Json::Num(replay_a.report.user_joins as f64),
        ),
        (
            "guard_leaves",
            Json::Num(replay_a.report.user_leaves as f64),
        ),
        (
            "baseline_goodput_s",
            Json::Num(baseline.report.goodput_s),
        ),
        ("sweep", Json::Arr(rows)),
    ];
    let path = std::path::PathBuf::from(&out);
    if write_suite_json(&path, "user_churn", &meta, &results) {
        println!("\nwrote {}", path.display());
    } else {
        println!("\ncould not write {} (read-only fs?)", path.display());
    }
}
