//! Wave-boundary invariant auditor (`[sim] audit` / `DRFH_AUDIT=1`).
//!
//! The static linter (`drfh lint`, [`crate::analysis`]) proves the
//! *source* obeys the determinism discipline; this module proves the
//! *running engine* obeys its invariants, by re-deriving ground truth
//! from the authoritative state after every event wave and comparing
//! it against everything the engine maintains incrementally. Enabled
//! by [`crate::sim::SimOpts::audit`], the `[sim] audit` config key, or
//! `DRFH_AUDIT=1`; the first violation panics with a structured dump
//! (timestamp, wave number, seq counter, policy name, every violated
//! invariant).
//!
//! Checked at every wave boundary (after the scheduler drain):
//!
//! * **capacity conservation** — per server: the PS run-entry count
//!   matches the committed task count, the vector sum of running
//!   demands matches the tracked usage to release/commit rounding
//!   (±1e-6 per component), and non-overcommitting policies never
//!   exceed capacity;
//! * **index-vs-naive decision cross-checks** — each policy's
//!   [`crate::sched::Scheduler::audit_indices`] hook re-proves its
//!   incremental indexes (`ShareHeap` / `ClassedShareIndex` argmin,
//!   `PlacementIndex` best-server) against fresh naive scans;
//! * **drain-order monotonicity** — every event popped off the
//!   [`crate::sim::wheel::ShardedQueue`] carries a strictly increasing
//!   `(time, seq)` key, whatever the lane routing or queue kind
//!   (noted at each pop, checked incrementally);
//! * **shard-ownership routing** — every queued `ServerCheck` sits on
//!   its owning shard's event lane, arrivals and samples on lane 0,
//!   and every queued event sorts strictly after the last drained one;
//! * **arena / user accounting** — per-job `unplaced <= open <= len`
//!   cursor consistency, per-user pending counts vs. the queued-job
//!   ring, per-user running counts vs. the PS run entries, the
//!   bitwise dominant-share invariant
//!   `dom_share == running as f64 * dom_delta` (recomputed, never
//!   accumulated — see `engine::commit_completion`), and the global
//!   placed-minus-completed balance;
//! * **fault invariants** (only when a fault plan is active) — every
//!   down server is fully drained (zero capacity, zero usage, no run
//!   entries, `can_fit` false for every pending user), and no attempt
//!   counter — on a run entry, a ready retry, or a backoff-parked slab
//!   payload — exceeds the configured retry budget;
//! * **churn invariants** (only when a churn plan is active) — every
//!   departed user is fully drained (no run entries on any server, no
//!   pending work, empty job/retry queues, no blocked membership, not
//!   eligible), and the absent-user count re-derives from the plan's
//!   initial absentees plus the effective join/leave counters;
//! * **blocked-set validity** — `eligible` is exactly the complement
//!   of the blocked set intersected with presence (`eligible[u] ==
//!   present[u] && !blocked[u]`; presence is all-true without churn),
//!   no eligible user still has pending work after a drain (post-wave
//!   quiescence), and every blocked user truly fits on *no* server
//!   under the policy's own [`crate::sched::Scheduler::can_fit`].
//!
//! Every check is read-only on engine state; the one mutating path —
//! the policies' index refresh + lazy pops inside `audit_indices` —
//! performs exactly the maintenance the next `pick`/`drain` would
//! have performed anyway, so an audited run's [`crate::sim::SimReport`]
//! is bit-identical to an unaudited one (`tests/engine_parity.rs`
//! pins this across the shard matrix).

use super::engine::{EventKind, Simulation};
use super::wheel::EventQueue;
use crate::cluster::ResVec;
use std::cmp::Ordering;

/// Absolute per-component tolerance for accumulated commit/release
/// float rounding (mirrors the residue clamp in
/// `cluster::Server::release`).
const TOL: f64 = 1e-6;

/// Cap on violations listed in one panic dump.
const MAX_DUMPED: usize = 16;

/// Auditor bookkeeping carried by the engine when auditing is on
/// (opaque outside the simulator; see the module docs).
pub struct AuditState {
    /// `(time, seq)` of the last drained event.
    last: Option<(f64, u64)>,
    /// Completed wave boundaries so far.
    waves: u64,
}

impl AuditState {
    pub fn new() -> Self {
        AuditState { last: None, waves: 0 }
    }
}

impl Default for AuditState {
    fn default() -> Self {
        Self::new()
    }
}

impl Simulation<'_> {
    /// Record one drained event and enforce drain-order monotonicity:
    /// the merged `(time, seq)` pop stream must be strictly
    /// increasing under the same total order every queue in
    /// [`crate::sim::wheel`] drains by. No-op when auditing is off.
    #[inline]
    pub(super) fn audit_note(&mut self, time: f64, seq: u64) {
        let Some(state) = &self.audit else { return };
        let last = state.last;
        if seq > self.seq {
            self.audit_fail(vec![format!(
                "drain-order: popped seq {seq} exceeds the push counter \
                 {}",
                self.seq
            )]);
        }
        if let Some((lt, ls)) = last {
            let ord =
                time.total_cmp(&lt).then_with(|| seq.cmp(&ls));
            if ord != Ordering::Greater {
                self.audit_fail(vec![format!(
                    "drain-order: popped ({time}, {seq}) does not sort \
                     strictly after the previous pop ({lt}, {ls})"
                )]);
            }
        }
        if let Some(state) = &mut self.audit {
            state.last = Some((time, seq));
        }
    }

    /// Run every wave-boundary check (module docs); panics with a
    /// structured dump on the first violating wave. No-op when
    /// auditing is off.
    pub(super) fn audit_wave(&mut self) {
        let Some(state) = &mut self.audit else { return };
        state.waves += 1;
        let mut violations: Vec<String> = Vec::new();

        self.audit_servers(&mut violations);
        self.audit_users(&mut violations);
        self.audit_arena(&mut violations);
        self.audit_blocked(&mut violations);
        self.audit_routing(&mut violations);
        self.audit_faults(&mut violations);
        self.audit_churn(&mut violations);
        if let Err(e) = self.scheduler.audit_indices(
            &self.cluster,
            &self.users,
            &self.eligible,
        ) {
            violations.push(format!("index-vs-naive: {e}"));
        }

        if !violations.is_empty() {
            self.audit_fail(violations);
        }
    }

    /// Per-server capacity conservation.
    fn audit_servers(&self, out: &mut Vec<String>) {
        let m = self.cluster.dims();
        let overcommit = self.scheduler.allows_overcommit();
        let mut total_running = 0usize;
        for (l, srv) in self.servers.iter().enumerate() {
            let s = &self.cluster.servers[l];
            total_running += srv.running.len();
            if s.tasks != srv.running.len() {
                out.push(format!(
                    "capacity: server {l} counts {} tasks but holds {} \
                     run entries",
                    s.tasks,
                    srv.running.len()
                ));
            }
            let mut sum = ResVec::zeros(m);
            for entry in srv.running.iter() {
                sum.add_assign(&self.users[entry.user as usize].demand);
            }
            for r in 0..m {
                if (sum[r] - s.usage[r]).abs() > TOL {
                    out.push(format!(
                        "capacity: server {l} resource {r} usage \
                         {:.9} != running-demand sum {:.9}",
                        s.usage[r], sum[r]
                    ));
                }
                if !overcommit && s.usage[r] > s.capacity[r] + TOL {
                    out.push(format!(
                        "capacity: server {l} resource {r} usage \
                         {:.9} exceeds capacity {:.9} without \
                         overcommit",
                        s.usage[r], s.capacity[r]
                    ));
                }
            }
        }
        // evicted placements — fault evictions (§Faults) and departure
        // evictions (§Churn) — left the PS without completing, so they
        // drop out of the balance
        let balance = self
            .report
            .tasks_placed
            .checked_sub(self.report.tasks_completed)
            .and_then(|b| b.checked_sub(self.report.evictions))
            .and_then(|b| b.checked_sub(self.churn_evicted));
        if balance != Some(total_running) {
            out.push(format!(
                "capacity: placed {} - completed {} - evicted {} - \
                 churn-evicted {} != {} total run entries",
                self.report.tasks_placed,
                self.report.tasks_completed,
                self.report.evictions,
                self.churn_evicted,
                total_running
            ));
        }
    }

    /// Per-user share/usage/counter accounting against the PS ground
    /// truth.
    fn audit_users(&self, out: &mut Vec<String>) {
        let m = self.cluster.dims();
        let mut running = vec![0usize; self.users.len()];
        for srv in &self.servers {
            for entry in srv.running.iter() {
                running[entry.user as usize] += 1;
            }
        }
        for (u, us) in self.users.iter().enumerate() {
            if us.running != running[u] {
                out.push(format!(
                    "user {u}: tracked running {} != {} run entries",
                    us.running, running[u]
                ));
            }
            // bitwise, not approximate: the engine recomputes the
            // product on every transition precisely so this never
            // drifts (see engine::commit_completion)
            let want = us.running as f64 * us.dom_delta;
            if us.dom_share.to_bits() != want.to_bits() {
                out.push(format!(
                    "user {u}: dom_share {:.17} is not bit-identical \
                     to running * dom_delta = {want:.17}",
                    us.dom_share
                ));
            }
            for r in 0..m {
                let want = us.running as f64 * us.demand[r];
                if (us.usage[r] - want).abs() > TOL {
                    out.push(format!(
                        "user {u}: usage[{r}] {:.9} != running * \
                         demand = {want:.9}",
                        us.usage[r]
                    ));
                }
            }
            // fired retries wait in `retry_ready` rather than the
            // arena, but count as pending until re-placed (§Faults)
            let queued: usize = self.queues[u]
                .iter()
                .map(|&j| self.arena.unplaced(j as usize))
                .sum::<usize>()
                + self.retry_ready[u].len();
            if us.pending != queued {
                out.push(format!(
                    "user {u}: pending {} != {} unplaced tasks across \
                     its queued jobs + ready retries",
                    us.pending, queued
                ));
            }
            for &j in &self.queues[u] {
                if self.arena.job_user(j as usize) != u {
                    out.push(format!(
                        "user {u}: queued job {j} belongs to user {}",
                        self.arena.job_user(j as usize)
                    ));
                }
            }
        }
    }

    /// Arena countdown/cursor consistency.
    fn audit_arena(&self, out: &mut Vec<String>) {
        for j in 0..self.arena.len() {
            let (unplaced, open, len) = (
                self.arena.unplaced(j),
                self.arena.open(j),
                self.arena.job_len(j),
            );
            if unplaced > open || open > len {
                out.push(format!(
                    "arena: job {j} cursors violate unplaced {unplaced} \
                     <= open {open} <= len {len}"
                ));
            }
        }
    }

    /// Blocked-set validity: `eligible` is the exact complement of the
    /// blocked index intersected with presence (all-present without
    /// churn), the wave left no eligible pending user behind, and
    /// every blocked user truly fits nowhere.
    fn audit_blocked(&self, out: &mut Vec<String>) {
        let k = self.cluster.len();
        let mut blocked_n = 0usize;
        for (u, us) in self.users.iter().enumerate() {
            let blocked = self.blocked.is_blocked(u);
            let present = !self.has_churn || self.present[u];
            if self.eligible[u] != (present && !blocked) {
                out.push(format!(
                    "blocked-set: user {u} eligible={} but \
                     is_blocked={blocked}, present={present}",
                    self.eligible[u]
                ));
                continue;
            }
            if !blocked {
                if present && us.pending > 0 {
                    out.push(format!(
                        "blocked-set: eligible user {u} still has {} \
                         pending tasks after the drain",
                        us.pending
                    ));
                }
                continue;
            }
            blocked_n += 1;
            // a completion on server l exact-probes every candidate
            // blocked class against l (engine::unblock_for_server),
            // so a blocked survivor must fit on no server at all
            if let Some(l) = (0..k).find(|&l| {
                self.scheduler.can_fit(&self.cluster, &self.users, u, l)
            }) {
                out.push(format!(
                    "blocked-set: blocked user {u} fits on server {l}"
                ));
            }
        }
        if blocked_n != self.blocked.len() {
            out.push(format!(
                "blocked-set: index reports {} members, eligibility \
                 mask implies {blocked_n}",
                self.blocked.len()
            ));
        }
    }

    /// Fault-layer invariants (§Faults in the engine docs): a down
    /// server is fully drained — zero capacity, zero usage, no run
    /// entries, and unplaceable under the policy's own `can_fit` (its
    /// absence from the placement heaps is proved separately by the
    /// `audit_indices` decision cross-check) — and no attempt counter
    /// anywhere (running, ready, or backoff-parked) exceeds the retry
    /// budget. Skipped when the fault plan is empty: nothing below can
    /// change, and the skip keeps audited no-fault runs byte-for-byte
    /// on the seed's check set.
    fn audit_faults(&self, out: &mut Vec<String>) {
        if !self.has_faults {
            return;
        }
        let m = self.cluster.dims();
        let cap = self.opts.retry.attempt_cap();
        for (l, &is_down) in self.down.iter().enumerate() {
            if !is_down {
                continue;
            }
            let s = &self.cluster.servers[l];
            for r in 0..m {
                if s.capacity[r] != 0.0 {
                    out.push(format!(
                        "faults: down server {l} holds capacity[{r}] = \
                         {:.9}, want 0",
                        s.capacity[r]
                    ));
                }
                if s.usage[r].abs() > TOL {
                    out.push(format!(
                        "faults: down server {l} holds usage[{r}] = \
                         {:.9}, want 0",
                        s.usage[r]
                    ));
                }
            }
            if s.tasks != 0 || !self.servers[l].running.is_empty() {
                out.push(format!(
                    "faults: down server {l} still runs {} tasks ({} \
                     run entries)",
                    s.tasks,
                    self.servers[l].running.len()
                ));
            }
            for (u, us) in self.users.iter().enumerate() {
                if us.pending > 0
                    && self.scheduler.can_fit(
                        &self.cluster,
                        &self.users,
                        u,
                        l,
                    )
                {
                    out.push(format!(
                        "faults: down server {l} reports can_fit for \
                         pending user {u}"
                    ));
                }
            }
        }
        for srv in &self.servers {
            for entry in srv.running.iter() {
                if entry.attempt < 1 || entry.attempt > cap {
                    out.push(format!(
                        "faults: run entry for user {} carries attempt \
                         {} outside 1..={cap}",
                        entry.user, entry.attempt
                    ));
                }
            }
        }
        for (u, ready) in self.retry_ready.iter().enumerate() {
            for rt in ready {
                if rt.attempt >= cap {
                    out.push(format!(
                        "faults: ready retry for user {u} already spent \
                         attempt {} of the {cap}-attempt budget",
                        rt.attempt
                    ));
                }
            }
        }
        // backoff-parked payloads: every queued Retry event must point
        // into the slab, at a payload still under budget
        self.events.for_each_lane(|_, ev| {
            if let EventKind::Retry { slot } = ev.payload {
                if slot as usize >= self.retry_pending.len() {
                    out.push(format!(
                        "faults: queued retry slot {slot} outside the \
                         {}-entry slab",
                        self.retry_pending.len()
                    ));
                } else if self.retry_pending[slot as usize].attempt >= cap
                {
                    out.push(format!(
                        "faults: parked retry in slot {slot} already \
                         spent attempt {} of the {cap}-attempt budget",
                        self.retry_pending[slot as usize].attempt
                    ));
                }
            }
        });
    }

    /// Churn-layer invariants (§Churn in the engine docs): every
    /// departed user is fully drained — no run entries on any server
    /// (re-derived from the PS heaps, not the tracked counter), no
    /// pending work, empty job ring and retry-ready queue, no blocked
    /// membership, not eligible — and the absent-user count re-derives
    /// from the plan's initial absentees plus the effective join/leave
    /// counters. Skipped when the churn plan is empty: presence is
    /// all-true by construction, and the skip keeps audited churn-free
    /// runs byte-for-byte on the pre-churn check set.
    fn audit_churn(&self, out: &mut Vec<String>) {
        if !self.has_churn {
            return;
        }
        let mut entries = vec![0usize; self.users.len()];
        for srv in &self.servers {
            for entry in srv.running.iter() {
                entries[entry.user as usize] += 1;
            }
        }
        let mut absent = 0usize;
        for (u, us) in self.users.iter().enumerate() {
            if self.present[u] {
                continue;
            }
            absent += 1;
            if entries[u] > 0 {
                out.push(format!(
                    "churn: departed user {u} still holds {} run \
                     entries",
                    entries[u]
                ));
            }
            if us.running != 0 || us.pending != 0 {
                out.push(format!(
                    "churn: departed user {u} tracks running {} / \
                     pending {}, want 0 / 0",
                    us.running, us.pending
                ));
            }
            if !self.queues[u].is_empty() || !self.retry_ready[u].is_empty()
            {
                out.push(format!(
                    "churn: departed user {u} keeps {} queued jobs and \
                     {} ready retries",
                    self.queues[u].len(),
                    self.retry_ready[u].len()
                ));
            }
            if self.blocked.is_blocked(u) {
                out.push(format!(
                    "churn: departed user {u} kept its blocked-set \
                     membership"
                ));
            }
            if self.eligible[u] {
                out.push(format!(
                    "churn: departed user {u} is still eligible"
                ));
            }
        }
        let want = self.opts.churn.absent_at_start.len() as i64
            + self.report.user_leaves as i64
            - self.report.user_joins as i64;
        if absent as i64 != want {
            out.push(format!(
                "churn: {absent} absent users, but initial {} + leaves \
                 {} - joins {} = {want}",
                self.opts.churn.absent_at_start.len(),
                self.report.user_leaves,
                self.report.user_joins
            ));
        }
    }

    /// Shard-ownership lane routing of every queued event, plus the
    /// queued-after-drained ordering bound.
    fn audit_routing(&self, out: &mut Vec<String>) {
        let last = self.audit.as_ref().and_then(|a| a.last);
        let push_seq = self.seq;
        self.events.for_each_lane(|lane, ev| {
            let want = match ev.payload {
                EventKind::ServerCheck { server, .. }
                | EventKind::ServerDown { server }
                | EventKind::ServerUp { server } => {
                    self.spec.owner_of(server)
                }
                EventKind::Arrival(_)
                | EventKind::Sample
                | EventKind::Retry { .. }
                | EventKind::UserJoin { .. }
                | EventKind::UserLeave { .. } => 0,
            };
            if lane != want {
                out.push(format!(
                    "routing: {:?} at ({}, {}) rides lane {lane}, owner \
                     lane is {want}",
                    ev.payload, ev.time, ev.seq
                ));
            }
            if ev.seq > push_seq {
                out.push(format!(
                    "routing: queued seq {} exceeds the push counter \
                     {push_seq}",
                    ev.seq
                ));
            }
            if let Some((lt, ls)) = last {
                let ord = ev
                    .time
                    .total_cmp(&lt)
                    .then_with(|| ev.seq.cmp(&ls));
                if ord != Ordering::Greater {
                    out.push(format!(
                        "routing: queued ({}, {}) does not sort after \
                         the last drained ({lt}, {ls})",
                        ev.time, ev.seq
                    ));
                }
            }
        });
    }

    /// Structured failure dump. Never returns.
    fn audit_fail(&self, violations: Vec<String>) -> ! {
        let shown = violations.len().min(MAX_DUMPED);
        let mut dump = String::new();
        for v in &violations[..shown] {
            dump.push_str("\n  * ");
            dump.push_str(v);
        }
        if violations.len() > shown {
            dump.push_str(&format!(
                "\n  * ... and {} more",
                violations.len() - shown
            ));
        }
        panic!(
            "DRFH audit failure: {} invariant violation(s) at t={:.6} \
             (wave {}, seq {}, scheduler '{}', {} servers, {} users, \
             {} queued events):{dump}",
            violations.len(),
            self.now,
            self.audit.as_ref().map_or(0, |a| a.waves),
            self.seq,
            self.scheduler.name(),
            self.cluster.len(),
            self.users.len(),
            self.events.len(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::Simulation;
    use crate::cluster::{Cluster, ResVec};
    use crate::sched::BestFitDrfh;
    use crate::sim::{run, ChurnEvent, ChurnPlan, SimOpts};
    use crate::workload::{JobSpec, TaskSpec, Trace, UserSpec};
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn two_user_trace() -> Trace {
        Trace {
            users: vec![
                UserSpec { demand: ResVec::cpu_mem(1.0, 1.0), weight: 1.0 },
                UserSpec { demand: ResVec::cpu_mem(1.0, 1.0), weight: 1.0 },
            ],
            jobs: vec![
                JobSpec {
                    id: 0,
                    user: 0,
                    submit: 0.0,
                    tasks: vec![TaskSpec { duration: 10.0 }; 2],
                },
                JobSpec {
                    id: 1,
                    user: 1,
                    submit: 0.0,
                    tasks: vec![TaskSpec { duration: 10.0 }; 2],
                },
            ],
        }
    }

    fn churn_opts() -> SimOpts {
        SimOpts {
            horizon: 100.0,
            sample_dt: 10.0,
            track_user_series: false,
            audit: true,
            churn: ChurnPlan::from_transitions(
                1,
                vec![],
                vec![
                    ChurnEvent { time: 5.0, user: 1, join: false },
                    ChurnEvent { time: 20.0, user: 1, join: true },
                ],
            ),
            ..SimOpts::default()
        }
    }

    /// A clean leave/rejoin run passes every wave-boundary check,
    /// including the churn set.
    #[test]
    fn audited_churn_run_passes() {
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(1.0, 1.0),
            ResVec::cpu_mem(1.0, 1.0),
        ]);
        let r = run(
            cluster,
            &two_user_trace(),
            Box::new(BestFitDrfh::default()),
            churn_opts(),
        );
        assert_eq!(r.user_leaves, 1);
        assert_eq!(r.user_joins, 1);
        // user 1 had one task running and one queued at t = 5
        assert_eq!(r.tasks_abandoned, 2);
        assert!(r.abandoned_s > 0.0);
    }

    /// A phantom departure — presence flipped off while the
    /// eligibility and accounting state still read "present" — must
    /// trip the auditor with a churn violation.
    #[test]
    fn phantom_departed_user_trips_the_audit() {
        let trace = two_user_trace();
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(1.0, 1.0),
            ResVec::cpu_mem(1.0, 1.0),
        ]);
        let mut sim = Simulation::new(
            cluster,
            &trace,
            Box::new(BestFitDrfh::naive()),
            churn_opts(),
        );
        // corrupt: user 1 departs without the engine's teardown — it
        // stays eligible and keeps its queue state
        sim.present[1] = false;
        let err = catch_unwind(AssertUnwindSafe(|| sim.audit_wave()))
            .expect_err("corrupted presence must trip the audit");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string panic payload".into());
        assert!(msg.contains("DRFH audit failure"), "{msg}");
        assert!(
            msg.contains("churn: departed user 1 is still eligible"),
            "{msg}"
        );
    }
}
