//! Faults experiment — server failures, retry with backoff, and
//! fairness recovery: Best-Fit DRFH vs Slots under an identical
//! deterministic fault plan, against a fault-free Best-Fit baseline
//! and the fluid allocator's degraded-pool reference.
//!
//! The plan mixes the three generator processes (independent Poisson
//! crash/repair per server, plus a one-off flash failure that downs a
//! fraction of the pool at once); both schedulers replay the *same*
//! plan on the same trace, so every difference in goodput, wasted
//! work, and recovery latency is the scheduler's. The fluid reference
//! uses [`IncrementalDrfh::set_class_count`] to shrink server-class
//! counts to the plan's peak concurrent outage and reports how far the
//! fair share floor drops while the pool is degraded.

use super::runner;
use super::{fig5, write_csv, EvalSetup};
use crate::allocator::{FluidUser, IncrementalDrfh};
use crate::sim::SimReport;
use crate::workload::{generate_faults, FaultGenConfig};

/// Reports for the fault comparison plus the fluid reference points.
#[derive(Clone, Debug)]
pub struct FaultsResult {
    /// Best-Fit DRFH with no faults injected (the control run).
    pub baseline: SimReport,
    /// Best-Fit DRFH under the fault plan.
    pub best_fit: SimReport,
    /// Slots-14 under the same fault plan.
    pub slots: SimReport,
    /// Fluid min dominant share on the full pool.
    pub fluid_nominal: f64,
    /// Fluid min dominant share at the plan's peak concurrent outage.
    pub fluid_degraded: f64,
    /// Largest number of servers down at once.
    pub peak_down: usize,
    /// Total down/up transitions in the compiled plan.
    pub plan_events: usize,
}

/// The default fault mix for `drfh exp faults`: sparse independent
/// crashes over the whole horizon plus a flash failure that downs a
/// quarter of the pool a third of the way in.
pub fn default_fault_config(horizon: f64) -> FaultGenConfig {
    FaultGenConfig {
        crash_rate: 2e-6,
        mean_downtime: 1_800.0,
        flash_at: Some(horizon / 3.0),
        flash_fraction: 0.25,
        flash_downtime: 3_600.0,
        ..FaultGenConfig::default()
    }
}

/// Run the comparison: generate the plan from `cfg`, replay it under
/// Best-Fit and Slots, run the fault-free Best-Fit control, and solve
/// the fluid references on the nominal and peak-degraded pools.
pub fn run_faults(setup: &EvalSetup, cfg: &FaultGenConfig) -> FaultsResult {
    let plan = generate_faults(
        cfg,
        setup.cluster.len(),
        setup.opts.horizon,
        setup.seed,
    );
    let plan_events = plan.events.len();

    // peak concurrent outage, tallied per server class for the fluid
    // reference (class index = position in `Cluster::classes`)
    let classes = setup.cluster.classes();
    let class_of: Vec<usize> = setup
        .cluster
        .servers
        .iter()
        .map(|s| {
            classes
                .iter()
                .position(|c| c.capacity == s.capacity)
                .expect("server capacity missing from its own class list")
        })
        .collect();
    let mut down = vec![false; setup.cluster.len()];
    let mut cur = 0usize;
    let mut peak_down = 0usize;
    let mut peak_per_class = vec![0usize; classes.len()];
    for ev in &plan.events {
        if ev.up {
            if down[ev.server] {
                down[ev.server] = false;
                cur -= 1;
            }
        } else if !down[ev.server] {
            down[ev.server] = true;
            cur += 1;
        }
        if cur > peak_down {
            peak_down = cur;
            peak_per_class.iter_mut().for_each(|c| *c = 0);
            for (l, &d) in down.iter().enumerate() {
                if d {
                    peak_per_class[class_of[l]] += 1;
                }
            }
        }
    }

    // fluid reference: fair share floor on the full pool, then with
    // each class shrunk by its peak outage (a pure rhs retune — the
    // warm basis survives), then restored
    let mut inc = IncrementalDrfh::new(&setup.cluster);
    for u in &setup.trace.users {
        inc.add_user(FluidUser {
            demand: u.demand,
            weight: u.weight,
            task_cap: None,
        });
    }
    let min_g = |g: &[f64]| g.iter().copied().fold(f64::INFINITY, f64::min);
    let fluid_nominal = min_g(&inc.allocate().g);
    for (c, &d) in peak_per_class.iter().enumerate() {
        if d > 0 {
            inc.set_class_count(c, classes[c].count - d);
        }
    }
    let fluid_degraded = min_g(&inc.allocate().g);

    // faulted head-to-head: the exact Fig. 6/7 pairing, same plan
    let mut fopts = setup.opts.clone();
    fopts.faults = plan;
    let mut faulted = runner::sweep(
        &setup.cluster,
        &setup.trace,
        &fopts,
        fig5::bestfit_vs_slots_factories(),
    );
    let slots = faulted.pop().expect("slots report");
    let best_fit = faulted.pop().expect("best-fit report");

    // fault-free control (FaultPlan::none() — bit-identical to the
    // pre-fault engine)
    let mut control = runner::sweep(
        &setup.cluster,
        &setup.trace,
        &setup.opts,
        vec![fig5::bestfit_vs_slots_factories().swap_remove(0)],
    );
    let baseline = control.pop().expect("baseline report");

    FaultsResult {
        baseline,
        best_fit,
        slots,
        fluid_nominal,
        fluid_degraded,
        peak_down,
        plan_events,
    }
}

/// `(resolved, total, mean recovery seconds over resolved)`.
fn recovery_stats(r: &SimReport) -> (usize, usize, f64) {
    let times: Vec<f64> =
        r.outages.iter().filter_map(|o| o.recovery_time()).collect();
    let mean = if times.is_empty() {
        0.0
    } else {
        times.iter().sum::<f64>() / times.len() as f64
    };
    (times.len(), r.outages.len(), mean)
}

pub fn print(res: &FaultsResult) {
    println!("== Faults: goodput, wasted work, fairness recovery ==");
    println!(
        "(plan: {} transitions, peak {} servers down at once)",
        res.plan_events, res.peak_down
    );
    println!(
        "{:<18} {:>11} {:>10} {:>7} {:>7} {:>6} {:>11} {:>10} {:>10}",
        "scheduler",
        "goodput h",
        "wasted h",
        "evict",
        "retry",
        "lost",
        "tasks done",
        "recovered",
        "mean rec s"
    );
    for (label, r) in [
        ("bestfit (clean)", &res.baseline),
        ("bestfit", &res.best_fit),
        ("slots-14", &res.slots),
    ] {
        let (resolved, total, mean) = recovery_stats(r);
        println!(
            "{:<18} {:>11.1} {:>10.1} {:>7} {:>7} {:>6} {:>11} {:>7}/{:<2} {:>10.0}",
            label,
            r.goodput_s / 3600.0,
            r.wasted_s / 3600.0,
            r.evictions,
            r.retries,
            r.tasks_lost,
            r.tasks_completed,
            resolved,
            total,
            mean,
        );
    }
    println!(
        "fluid min dominant share: nominal {:.4} -> degraded {:.4} \
         (peak outage removes {:.1}% of it)",
        res.fluid_nominal,
        res.fluid_degraded,
        if res.fluid_nominal > 0.0 {
            (1.0 - res.fluid_degraded / res.fluid_nominal) * 100.0
        } else {
            0.0
        }
    );
    // per-outage recovery CSV (Best-Fit run)
    let rows: Vec<String> = res
        .best_fit
        .outages
        .iter()
        .map(|o| {
            format!(
                "{:.1},{},{:.6},{},{}",
                o.at,
                o.server,
                o.baseline_envy,
                o.recovered_at.map_or(String::new(), |t| format!("{t:.1}")),
                o.recovery_time()
                    .map_or(String::new(), |t| format!("{t:.1}")),
            )
        })
        .collect();
    write_csv(
        "faults_recovery.csv",
        "crash_t,server,baseline_envy,recovered_at,recovery_s",
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_run_conserves_work_and_recovers() {
        let setup = EvalSetup::with_duration(17, 60, 8, 6_000.0);
        let cfg = FaultGenConfig {
            crash_rate: 1e-5,
            mean_downtime: 600.0,
            flash_at: Some(1_500.0),
            flash_fraction: 0.25,
            flash_downtime: 1_200.0,
            // generous tolerance: every outage must resolve at the
            // first sample tick, making recovery deterministic to test
            envy_eps: 1e9,
            ..FaultGenConfig::default()
        };
        let res = run_faults(&setup, &cfg);

        // the flash failure lands in the saturated regime: something
        // must actually get evicted and retried
        assert!(res.plan_events > 0);
        assert!(res.peak_down >= 15, "peak {}", res.peak_down);
        assert!(res.best_fit.evictions > 0, "flash evicted nothing");
        // every eviction either re-queues or exhausts its budget
        assert_eq!(
            res.best_fit.evictions,
            res.best_fit.retries + res.best_fit.tasks_lost
        );
        assert!(res.best_fit.wasted_s > 0.0);

        // work conservation: a task's completing attempt carries only
        // its remaining duration, so goodput + wasted never exceeds
        // the trace's total service demand
        let total_work: f64 = setup
            .trace
            .jobs
            .iter()
            .flat_map(|j| &j.tasks)
            .map(|t| t.duration)
            .sum();
        for r in [&res.baseline, &res.best_fit, &res.slots] {
            assert!(
                r.goodput_s + r.wasted_s <= total_work + 1e-6,
                "{}: goodput {} + wasted {} > demand {}",
                r.scheduler,
                r.goodput_s,
                r.wasted_s,
                total_work
            );
        }
        // the control run injects nothing
        assert_eq!(res.baseline.evictions, 0);
        assert_eq!(res.baseline.wasted_s, 0.0);
        assert!(res.baseline.outages.is_empty());

        // with an unbounded tolerance every outage resolves at the
        // first sample tick after its crash
        let downs = res.plan_events / 2;
        assert_eq!(res.best_fit.outages.len(), downs);
        assert!(res
            .best_fit
            .outages
            .iter()
            .all(|o| o.recovered_at.is_some()));

        // shrinking the pool can only lower the fluid share floor
        assert!(res.fluid_nominal.is_finite() && res.fluid_nominal > 0.0);
        assert!(res.fluid_degraded <= res.fluid_nominal + 1e-9);
    }
}
