//! A single heterogeneous server: capacity vector plus the usage the
//! scheduler has committed to it.
//!
//! Usage (not "available") is the primary state so that the Slots
//! baseline can *overcommit* a server — the paper's slot scheduler
//! ignores real resource demands, and modelling its inefficiency
//! requires letting usage exceed capacity (see `sim::engine` for the
//! processor-sharing slowdown that results).

use super::vector::ResVec;

/// Tolerance used in feasibility checks; demands accumulate over many
/// f64 adds/subs, so exact comparisons would spuriously reject fits.
pub const FIT_EPS: f64 = 1e-9;

/// One server in the pool.
#[derive(Clone, Debug)]
pub struct Server {
    /// Total resources of the server (absolute units).
    pub capacity: ResVec,
    /// Resources currently committed to running tasks. May exceed
    /// capacity only under overcommitting schedulers (Slots).
    pub usage: ResVec,
    /// Index of the configuration class the server was sampled from
    /// (provenance for experiments; 0 when hand-built).
    pub class: usize,
    /// Number of tasks currently running on the server (the Slots
    /// baseline keys its per-server slot accounting off this).
    pub tasks: usize,
}

impl Server {
    /// New empty server.
    pub fn new(capacity: ResVec) -> Self {
        let m = capacity.dims();
        Server { capacity, usage: ResVec::zeros(m), class: 0, tasks: 0 }
    }

    /// New empty server tagged with its configuration class.
    pub fn with_class(capacity: ResVec, class: usize) -> Self {
        Server { class, ..Self::new(capacity) }
    }

    /// Resources still available (capacity - usage), clamped at 0 per
    /// component for overcommitted servers.
    pub fn available(&self) -> ResVec {
        let mut a = self.capacity.sub(&self.usage);
        for i in 0..a.dims() {
            if a[i] < 0.0 {
                a[i] = 0.0;
            }
        }
        a
    }

    /// Would `demand` fit without overcommitting?
    #[inline]
    pub fn fits(&self, demand: &ResVec) -> bool {
        self.usage.add(demand).le_eps(&self.capacity, FIT_EPS)
    }

    /// Raw headroom on resource `r` (capacity − usage, *unclamped*:
    /// negative under overcommit). The scheduling index keys off this
    /// exact expression — see `sched::index`.
    #[inline]
    pub fn headroom(&self, r: usize) -> f64 {
        self.capacity[r] - self.usage[r]
    }

    /// Smallest per-resource headroom — the upper bound on the
    /// minimum demand component of any task that fits this server
    /// (the `BlockedIndex` re-check key).
    #[inline]
    pub fn min_headroom(&self) -> f64 {
        let mut h = f64::INFINITY;
        for r in 0..self.capacity.dims() {
            h = h.min(self.headroom(r));
        }
        h
    }

    /// Commit resources (no feasibility check — callers decide whether
    /// overcommit is allowed).
    #[inline]
    pub fn commit(&mut self, demand: &ResVec) {
        self.usage.add_assign(demand);
    }

    /// Release resources, clamping tiny negative residue from float
    /// accumulation back to zero.
    #[inline]
    pub fn release(&mut self, demand: &ResVec) {
        self.usage.sub_assign(demand);
        for i in 0..self.usage.dims() {
            if self.usage[i] < 0.0 {
                debug_assert!(self.usage[i] > -1e-6, "usage went negative");
                self.usage[i] = 0.0;
            }
        }
    }

    /// Highest usage/capacity ratio across resources (>1 = overcommit).
    pub fn load(&self) -> f64 {
        self.usage.max_ratio(&self.capacity)
    }

    /// Processor-sharing rate factor: 1 within capacity; 1/load³ when
    /// overcommitted. The superlinear term models thrashing (paging,
    /// context-switch overhead) on top of the 1/load fair-sharing
    /// slowdown — without it overcommit would be work-conserving and
    /// the paper's Table II utilization drop at 20 slots could not
    /// occur; the cubic exponent is calibrated so the Table II hump
    /// lands at 14-16 slots as in the paper (see DESIGN.md §4).
    pub fn rate(&self) -> f64 {
        let l = self.load();
        if l <= 1.0 {
            1.0
        } else {
            1.0 / (l * l * l)
        }
    }

    /// Resources making *progress* on this server: usage discounted by
    /// the slowdown factor (== usage when not overcommitted).
    pub fn effective_usage(&self) -> ResVec {
        let f = self.rate();
        let mut e = self.usage;
        for r in 0..e.dims() {
            e[r] = (e[r] * f).min(self.capacity[r]);
        }
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_and_commit_release() {
        let mut s = Server::new(ResVec::cpu_mem(4.0, 8.0));
        let d = ResVec::cpu_mem(1.0, 2.0);
        assert!(s.fits(&d));
        s.commit(&d);
        s.commit(&d);
        assert_eq!(s.usage, ResVec::cpu_mem(2.0, 4.0));
        assert!(s.fits(&ResVec::cpu_mem(2.0, 4.0)));
        assert!(!s.fits(&ResVec::cpu_mem(2.1, 1.0)));
        s.release(&d);
        assert_eq!(s.usage, d);
    }

    #[test]
    fn available_clamps_overcommit() {
        let mut s = Server::new(ResVec::cpu_mem(1.0, 1.0));
        s.commit(&ResVec::cpu_mem(1.5, 0.5));
        assert_eq!(s.available(), ResVec::cpu_mem(0.0, 0.5));
        assert!((s.load() - 1.5).abs() < 1e-12);
        assert!((s.rate() - 1.0 / 3.375).abs() < 1e-12);
        let e = s.effective_usage();
        assert!((e[0] - 1.5 / 3.375).abs() < 1e-12);
        assert!((e[1] - 0.5 / 3.375).abs() < 1e-12);
    }

    #[test]
    fn rate_is_one_within_capacity() {
        let mut s = Server::new(ResVec::cpu_mem(2.0, 2.0));
        s.commit(&ResVec::cpu_mem(1.0, 1.0));
        assert_eq!(s.rate(), 1.0);
    }

    #[test]
    fn fit_eps_tolerates_float_residue() {
        let mut s = Server::new(ResVec::cpu_mem(1.0, 1.0));
        let d = ResVec::cpu_mem(0.1, 0.1);
        for _ in 0..10 {
            assert!(s.fits(&d), "residue rejected fit at usage {}", s.usage);
            s.commit(&d);
        }
    }
}
