"""AOT-lower the L2 scheduling graphs to HLO text artifacts.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits one artifact per (n, k, m[, steps]) shape variant plus a
manifest.json the Rust runtime uses for discovery. `make artifacts` is a
no-op when artifacts are newer than their Python inputs.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shape variants compiled ahead of time. The Rust coordinator pads its
# live state (users up to n, servers up to k) into the smallest variant
# that fits. Tiles are 128 wide, so k and n are powers of two.
STEP_VARIANTS = [
    # (n_users, k_servers, m_resources)
    (4, 16, 2),
    (8, 32, 3),
    (16, 128, 2),
    (64, 512, 2),
    (128, 2048, 2),
]
LOOP_VARIANTS = [
    # (n_users, k_servers, m_resources, steps)
    (16, 128, 2, 32),
    (64, 512, 2, 64),
    (128, 2048, 2, 64),
]


def to_hlo_text(lowered) -> str:
    """jax lowering -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_step(n: int, k: int, m: int) -> str:
    f32 = jnp.float32
    i32 = jnp.int32
    lowered = jax.jit(model.sched_step).lower(
        jax.ShapeDtypeStruct((k, m), f32),  # avail
        jax.ShapeDtypeStruct((n, m), f32),  # demand
        jax.ShapeDtypeStruct((n,), f32),  # share
        jax.ShapeDtypeStruct((n,), f32),  # weight
        jax.ShapeDtypeStruct((n,), i32),  # active
    )
    return to_hlo_text(lowered)


def lower_loop(n: int, k: int, m: int, steps: int) -> str:
    f32 = jnp.float32
    i32 = jnp.int32
    fn = functools.partial(model.sched_loop, steps=steps)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((k, m), f32),  # avail
        jax.ShapeDtypeStruct((n, m), f32),  # demand
        jax.ShapeDtypeStruct((n,), f32),  # share
        jax.ShapeDtypeStruct((n,), f32),  # weight
        jax.ShapeDtypeStruct((n,), i32),  # pending
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"step": [], "loop": []}
    for n, k, m in STEP_VARIANTS:
        name = f"sched_step_n{n}_k{k}_m{m}.hlo.txt"
        text = lower_step(n, k, m)
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        manifest["step"].append({"n": n, "k": k, "m": m, "file": name})
        print(f"wrote {name} ({len(text)} chars)")

    for n, k, m, steps in LOOP_VARIANTS:
        name = f"sched_loop_n{n}_k{k}_m{m}_t{steps}.hlo.txt"
        text = lower_loop(n, k, m, steps)
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        manifest["loop"].append(
            {"n": n, "k": k, "m": m, "steps": steps, "file": name}
        )
        print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['step'])} step, "
          f"{len(manifest['loop'])} loop variants)")


if __name__ == "__main__":
    main()
