//! Trace data model: what the simulator replays.

use crate::cluster::ResVec;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// A cloud user (tenant). Per the paper's model each user has one
/// per-task resource demand vector `D_i` (absolute units) and a weight.
#[derive(Clone, Debug)]
pub struct UserSpec {
    /// Per-task demand vector (absolute units, e.g. cores / GB).
    pub demand: ResVec,
    /// Fair-share weight (paper Sec. V-A); 1.0 = unweighted.
    pub weight: f64,
}

/// One task of a job: the demand comes from the owning user's spec;
/// the duration is the task's service requirement at rate 1.
#[derive(Clone, Copy, Debug)]
pub struct TaskSpec {
    pub duration: f64,
}

/// A job: a batch of tasks submitted together by one user.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: usize,
    pub user: usize,
    /// Submission time (seconds from trace start).
    pub submit: f64,
    pub tasks: Vec<TaskSpec>,
}

impl JobSpec {
    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }
}

/// A complete workload trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub users: Vec<UserSpec>,
    /// Jobs sorted by submission time.
    pub jobs: Vec<JobSpec>,
}

impl Trace {
    /// Total number of tasks across all jobs.
    pub fn total_tasks(&self) -> usize {
        self.jobs.iter().map(|j| j.num_tasks()).sum()
    }

    /// Tasks per user.
    pub fn tasks_per_user(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.users.len()];
        for j in &self.jobs {
            counts[j.user] += j.num_tasks();
        }
        counts
    }

    /// Latest submission time.
    pub fn horizon(&self) -> f64 {
        self.jobs.iter().map(|j| j.submit).fold(0.0, f64::max)
    }

    /// Serialize to JSON (reproducibility capsules for EXPERIMENTS.md).
    pub fn to_json(&self) -> String {
        let users = Json::Arr(
            self.users
                .iter()
                .map(|u| {
                    let mut o = BTreeMap::new();
                    o.insert(
                        "demand".into(),
                        Json::Arr(
                            u.demand
                                .as_slice()
                                .iter()
                                .map(|&x| Json::Num(x))
                                .collect(),
                        ),
                    );
                    o.insert("weight".into(), Json::Num(u.weight));
                    Json::Obj(o)
                })
                .collect(),
        );
        let jobs = Json::Arr(
            self.jobs
                .iter()
                .map(|j| {
                    let mut o = BTreeMap::new();
                    o.insert("id".into(), Json::Num(j.id as f64));
                    o.insert("user".into(), Json::Num(j.user as f64));
                    o.insert("submit".into(), Json::Num(j.submit));
                    o.insert(
                        "tasks".into(),
                        Json::Arr(
                            j.tasks
                                .iter()
                                .map(|t| Json::Num(t.duration))
                                .collect(),
                        ),
                    );
                    Json::Obj(o)
                })
                .collect(),
        );
        let mut root = BTreeMap::new();
        root.insert("users".into(), users);
        root.insert("jobs".into(), jobs);
        Json::Obj(root).to_string()
    }

    /// Parse from JSON produced by [`Trace::to_json`].
    pub fn from_json(s: &str) -> Result<Self, String> {
        let v = json::parse(s)?;
        let users = v
            .get("users")
            .and_then(Json::as_arr)
            .ok_or("missing users")?
            .iter()
            .map(|u| {
                let demand: Vec<f64> = u
                    .get("demand")
                    .and_then(Json::as_arr)
                    .ok_or("missing demand")?
                    .iter()
                    .map(|x| x.as_f64().ok_or("bad demand"))
                    .collect::<Result<_, _>>()?;
                Ok(UserSpec {
                    demand: ResVec::from_slice(&demand),
                    weight: u
                        .get("weight")
                        .and_then(Json::as_f64)
                        .unwrap_or(1.0),
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let jobs = v
            .get("jobs")
            .and_then(Json::as_arr)
            .ok_or("missing jobs")?
            .iter()
            .map(|j| {
                let tasks = j
                    .get("tasks")
                    .and_then(Json::as_arr)
                    .ok_or("missing tasks")?
                    .iter()
                    .map(|t| {
                        t.as_f64()
                            .map(|duration| TaskSpec { duration })
                            .ok_or("bad task")
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(JobSpec {
                    id: j.get("id").and_then(Json::as_usize).ok_or("id")?,
                    user: j
                        .get("user")
                        .and_then(Json::as_usize)
                        .ok_or("user")?,
                    submit: j
                        .get("submit")
                        .and_then(Json::as_f64)
                        .ok_or("submit")?,
                    tasks,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Trace { users, jobs })
    }

    /// Sanity checks: sorted submits, valid user ids, positive demands
    /// and durations. Returns a description of the first violation.
    pub fn validate(&self) -> Result<(), String> {
        let mut last = 0.0;
        for j in &self.jobs {
            if j.user >= self.users.len() {
                return Err(format!("job {} has invalid user {}", j.id, j.user));
            }
            if j.submit < last {
                return Err(format!("job {} submitted out of order", j.id));
            }
            last = j.submit;
            if j.tasks.is_empty() {
                return Err(format!("job {} has no tasks", j.id));
            }
            for t in &j.tasks {
                if !(t.duration > 0.0) {
                    return Err(format!("job {} has non-positive duration", j.id));
                }
            }
        }
        for (i, u) in self.users.iter().enumerate() {
            if !u.demand.all_positive() {
                return Err(format!("user {i} has non-positive demand"));
            }
            // zero weights are legal: every consumer ranks through the
            // guarded `sched::effective_weight` (0 -> 1.0), matching
            // the f32 picker and the Pallas kernel. Non-finite weights
            // are not: an infinite weight collapses every share key to
            // 0, which the class-keyed scheduler state
            // (`sched::users`) relies on validate to exclude.
            if !(u.weight >= 0.0 && u.weight.is_finite()) {
                return Err(format!(
                    "user {i} has negative or non-finite weight"
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Trace {
        Trace {
            users: vec![UserSpec {
                demand: ResVec::cpu_mem(0.2, 0.3),
                weight: 1.0,
            }],
            jobs: vec![JobSpec {
                id: 0,
                user: 0,
                submit: 1.0,
                tasks: vec![TaskSpec { duration: 5.0 }; 3],
            }],
        }
    }

    #[test]
    fn counts_and_horizon() {
        let t = tiny();
        assert_eq!(t.total_tasks(), 3);
        assert_eq!(t.tasks_per_user(), vec![3]);
        assert_eq!(t.horizon(), 1.0);
        t.validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let t = tiny();
        let s = t.to_json();
        let t2 = Trace::from_json(&s).unwrap();
        assert_eq!(t2.total_tasks(), 3);
        assert_eq!(t2.users[0].demand, t.users[0].demand);
        assert_eq!(t2.jobs[0].submit, 1.0);
        assert_eq!(t2.jobs[0].tasks[0].duration, 5.0);
    }

    #[test]
    fn validate_rejects_non_finite_or_negative_weight() {
        for w in [f64::INFINITY, f64::NAN, -1.0] {
            let mut t = tiny();
            t.users[0].weight = w;
            assert!(t.validate().is_err(), "weight {w} must be rejected");
        }
    }

    #[test]
    fn validate_rejects_bad_user() {
        let mut t = tiny();
        t.jobs[0].user = 7;
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_rejects_unsorted() {
        let mut t = tiny();
        let mut j = t.jobs[0].clone();
        j.id = 1;
        j.submit = 0.5;
        t.jobs.push(j);
        assert!(t.validate().is_err());
    }

    #[test]
    fn from_json_rejects_malformed() {
        assert!(Trace::from_json("{}").is_err());
        assert!(Trace::from_json("not json").is_err());
    }
}
