//! Regenerates paper Table II (Slots scheduler utilization vs slot
//! size — the 5-point sweep now fans out through
//! `experiments::runner`) and times one sweep point on the indexed
//! vs naive Slots user-selection paths.
//!
//! Run: `cargo bench --bench table2_slots`
//! CI smoke: `TABLE2_SMOKE=1 cargo bench --bench table2_slots`
//! Full-scale sweep: `drfh exp table2 --servers 2000`

use drfh::experiments::{table2, EvalSetup};
use drfh::sched::SlotsScheduler;
use drfh::sim::run;
use drfh::util::bench::{bench, header};
use std::time::Duration;

fn main() {
    // bench-scale setup: 300 servers / 30 users / 6 h keeps the sweep
    // shape while finishing quickly (scale with `drfh exp table2`);
    // TABLE2_SMOKE trims it further for CI.
    let smoke = std::env::var_os("TABLE2_SMOKE").is_some();
    let setup = if smoke {
        EvalSetup::with_duration(42, 120, 12, 7_200.0)
    } else {
        EvalSetup::with_duration(42, 300, 30, 21_600.0)
    };
    let rows = table2::run_table2(&setup);
    table2::print(&rows);

    header("table2: one slots-scheduler simulation, indexed vs naive");
    let (budget, iters) = if smoke {
        (Duration::from_millis(500), 3)
    } else {
        (Duration::from_secs(5), 20)
    };
    for &slots in &[10usize, 14, 20] {
        let mut counts_indexed = (0usize, 0usize);
        let indexed = bench(
            &format!("slots={slots} indexed users"),
            budget,
            iters,
            || {
                let r = run(
                    setup.cluster.clone(),
                    &setup.trace,
                    Box::new(SlotsScheduler::new(&setup.cluster, slots)),
                    setup.opts.clone(),
                );
                counts_indexed = (r.tasks_placed, r.tasks_completed);
                counts_indexed
            },
        );
        let mut counts_naive = (0usize, 0usize);
        let naive = bench(
            &format!("slots={slots} naive users"),
            budget,
            iters,
            || {
                let r = run(
                    setup.cluster.clone(),
                    &setup.trace,
                    Box::new(SlotsScheduler::naive(&setup.cluster, slots)),
                    setup.opts.clone(),
                );
                counts_naive = (r.tasks_placed, r.tasks_completed);
                counts_naive
            },
        );
        // cheap parity guard on the runs the bench just timed; the
        // full pick-stream proof lives in tests/engine_parity.rs
        assert_eq!(
            counts_indexed, counts_naive,
            "slots={slots}: indexed/naive diverged"
        );
        println!(
            "slots={slots}: indexed {:.2}x vs naive (identical decisions)",
            naive.p50.as_secs_f64() / indexed.p50.as_secs_f64().max(1e-12)
        );
    }
}
