//! Incremental-vs-scratch parity: `allocator::incremental::IncrementalDrfh`
//! must match the from-scratch `allocator::solve` after *every* event
//! of randomized join/depart/cap-change/weight-change sequences, within
//! 1e-9 per resource — while actually re-using the warm simplex basis
//! (pivot counts must drop vs the from-scratch path).
//!
//! The comparison targets the quantities that are unique across
//! alternate LP optima: the dominant shares `g` and each user's
//! per-resource pool-share totals (`Σ_c x_ic · d_ir = g_i · d_ir`).
//! The per-class split may legitimately differ between two optimal
//! solutions and is not compared.

use drfh::allocator::incremental::{IncrementalDrfh, UserId};
use drfh::allocator::{self, FluidAllocation, FluidUser};
use drfh::cluster::{Cluster, ResVec};
use drfh::util::Pcg32;

fn random_user(rng: &mut Pcg32) -> FluidUser {
    FluidUser {
        demand: ResVec::cpu_mem(
            rng.uniform(0.05, 1.0),
            rng.uniform(0.05, 1.0),
        ),
        weight: if rng.f64() < 0.4 { rng.uniform(0.5, 3.0) } else { 1.0 },
        task_cap: if rng.f64() < 0.35 {
            Some(rng.uniform(0.0, 25.0))
        } else {
            None
        },
    }
}

fn assert_parity(warm: &FluidAllocation, scratch: &FluidAllocation, ctx: &str) {
    assert_eq!(warm.g.len(), scratch.g.len(), "{ctx}: user count");
    let m = warm.total.dims();
    for i in 0..warm.g.len() {
        assert!(
            (warm.g[i] - scratch.g[i]).abs() < 1e-9,
            "{ctx}: user {i} dominant share {} vs {}",
            warm.g[i],
            scratch.g[i]
        );
        for r in 0..m {
            let w: f64 = (0..warm.classes.len())
                .map(|c| warm.alloc_share(i, c)[r])
                .sum();
            let s: f64 = (0..scratch.classes.len())
                .map(|c| scratch.alloc_share(i, c)[r])
                .sum();
            assert!(
                (w - s).abs() < 1e-9,
                "{ctx}: user {i} resource {r}: {w} vs {s}"
            );
        }
        assert!(
            (warm.tasks[i] - scratch.tasks[i]).abs()
                < 1e-6 * (1.0 + scratch.tasks[i].abs()),
            "{ctx}: user {i} tasks {} vs {}",
            warm.tasks[i],
            scratch.tasks[i]
        );
    }
    assert!(warm.is_feasible(1e-7), "{ctx}: warm allocation infeasible");
}

/// The headline property: parity after every event of a random stream,
/// on an independently maintained mirror (catches ordering bugs that a
/// `inc.users()`-based reference would mask).
#[test]
fn random_event_sequences_match_scratch() {
    for seed in 0..12u64 {
        let mut rng = Pcg32::seeded(500 + seed);
        let k = 5 + rng.below(40);
        let cluster = Cluster::google_sample(k, &mut rng);
        let mut inc = IncrementalDrfh::new(&cluster);
        let mut ids: Vec<UserId> = Vec::new();
        let mut mirror: Vec<FluidUser> = Vec::new();
        for _ in 0..2 + rng.below(3) {
            let u = random_user(&mut rng);
            ids.push(inc.add_user(u.clone()));
            mirror.push(u);
        }
        for ev in 0..24 {
            let r = rng.f64();
            if (r < 0.3 && ids.len() < 8) || ids.len() <= 1 {
                let u = random_user(&mut rng);
                ids.push(inc.add_user(u.clone()));
                mirror.push(u);
            } else if r < 0.5 {
                let i = rng.below(ids.len());
                inc.remove_user(ids.remove(i));
                mirror.remove(i);
            } else if r < 0.75 {
                let i = rng.below(ids.len());
                let cap = if rng.f64() < 0.5 {
                    Some(rng.uniform(0.0, 30.0))
                } else {
                    None
                };
                inc.set_cap(ids[i], cap);
                mirror[i].task_cap = cap;
            } else {
                let i = rng.below(ids.len());
                let w = rng.uniform(0.25, 4.0);
                inc.set_weight(ids[i], w);
                mirror[i].weight = w;
            }
            let warm = inc.allocate();
            let scratch = allocator::solve(&cluster, &mirror);
            assert_parity(&warm, &scratch, &format!("seed {seed} event {ev}"));
        }
        let st = inc.solver_stats();
        assert!(st.warm_solves > 0, "seed {seed}: no warm solves: {st:?}");
    }
}

/// The warm path must actually be cheaper: across a churny stream the
/// incremental allocator's search-pivot total stays below the
/// from-scratch re-solves'.
#[test]
fn warm_start_saves_pivots() {
    let mut rng = Pcg32::seeded(77);
    let cluster = Cluster::google_sample(500, &mut rng);
    let mut inc = IncrementalDrfh::new(&cluster);
    let users: Vec<FluidUser> = (0..16).map(|_| random_user(&mut rng)).collect();
    let mut ids: Vec<UserId> =
        users.iter().map(|u| inc.add_user(u.clone())).collect();
    let mut mirror = users;
    let mut warm_pivots = 0u64;
    let mut scratch_pivots = 0u64;
    for step in 0..20usize {
        let i = step % mirror.len();
        let cap = if step % 2 == 0 { Some(5.0 + step as f64) } else { None };
        inc.set_cap(ids[i], cap);
        mirror[i].task_cap = cap;
        if step == 10 {
            inc.remove_user(ids.remove(0));
            mirror.remove(0);
            let u = random_user(&mut rng);
            ids.push(inc.add_user(u.clone()));
            mirror.push(u);
        }
        let warm = inc.allocate();
        let scratch = allocator::solve(&cluster, &mirror);
        assert_parity(&warm, &scratch, &format!("step {step}"));
        warm_pivots += warm.lp_pivots;
        scratch_pivots += scratch.lp_pivots;
    }
    assert!(
        warm_pivots < scratch_pivots,
        "warm {warm_pivots} >= scratch {scratch_pivots}"
    );
    let st = inc.solver_stats();
    assert!(st.warm_solves > 0, "warm path never used: {st:?}");
}

/// Stress the slot recycler: drain the population to one user and
/// rebuild it several times; parity must survive every generation.
#[test]
fn repeated_drain_and_refill_keeps_parity() {
    let mut rng = Pcg32::seeded(9090);
    let cluster = Cluster::google_sample(30, &mut rng);
    let mut inc = IncrementalDrfh::new(&cluster);
    let mut ids: Vec<UserId> = Vec::new();
    let mut mirror: Vec<FluidUser> = Vec::new();
    for gen in 0..3 {
        for _ in 0..5 {
            let u = random_user(&mut rng);
            ids.push(inc.add_user(u.clone()));
            mirror.push(u);
            let warm = inc.allocate();
            let scratch = allocator::solve(&cluster, &mirror);
            assert_parity(&warm, &scratch, &format!("gen {gen} grow"));
        }
        while ids.len() > 1 {
            let i = rng.below(ids.len());
            inc.remove_user(ids.remove(i));
            mirror.remove(i);
            let warm = inc.allocate();
            let scratch = allocator::solve(&cluster, &mirror);
            assert_parity(&warm, &scratch, &format!("gen {gen} shrink"));
        }
    }
}

/// Equal-split determinism and envy-freeness *within* an allocation
/// class, maintained across churn: after every event of a
/// join/depart/cap/weight stream drawn from chunky archetype pools
/// (so bit-identical (demand, weight, cap) triples actually recur),
/// users sharing a triple must hold **bitwise identical** allocations
/// — same dominant share, same per-class split, same task count — so
/// no class member can envy another. The scratch path is cross-checked
/// on top so the property can't be satisfied by a wrong-but-symmetric
/// allocation.
#[test]
fn class_members_split_bitwise_under_event_stream() {
    let demand_pool = [
        ResVec::cpu_mem(0.25, 1.0),
        ResVec::cpu_mem(1.0, 0.25),
        ResVec::cpu_mem(0.5, 0.5),
    ];
    let weight_pool = [1.0, 2.0];
    let cap_pool = [None, Some(6.0), Some(18.0)];
    let mut rng = Pcg32::seeded(31337);
    let cluster = Cluster::google_sample(40, &mut rng);
    let mut inc = IncrementalDrfh::new(&cluster);
    let mut ids: Vec<UserId> = Vec::new();
    let mut mirror: Vec<FluidUser> = Vec::new();
    let mut collapsed_any = false;
    for ev in 0..40 {
        let r = rng.f64();
        if (r < 0.4 && ids.len() < 14) || ids.len() <= 2 {
            let u = FluidUser {
                demand: demand_pool[rng.below(demand_pool.len())],
                weight: weight_pool[rng.below(weight_pool.len())],
                task_cap: cap_pool[rng.below(cap_pool.len())],
            };
            ids.push(inc.add_user(u.clone()));
            mirror.push(u);
        } else if r < 0.55 {
            let i = rng.below(ids.len());
            inc.remove_user(ids.remove(i));
            mirror.remove(i);
        } else if r < 0.8 {
            let i = rng.below(ids.len());
            let cap = cap_pool[rng.below(cap_pool.len())];
            inc.set_cap(ids[i], cap);
            mirror[i].task_cap = cap;
        } else {
            let i = rng.below(ids.len());
            let w = weight_pool[rng.below(weight_pool.len())];
            inc.set_weight(ids[i], w);
            mirror[i].weight = w;
        }
        let warm = inc.allocate();

        // group users by exact spec bits (a refinement of the
        // allocator's class key: same absolute demand + same weight +
        // same task cap certainly shares an allocation class);
        // linear-scan grouping keeps the traversal deterministic
        let key_of = |u: &FluidUser| -> (u64, u64, u64, u64) {
            (
                u.demand[0].to_bits(),
                u.demand[1].to_bits(),
                u.weight.to_bits(),
                u.task_cap.unwrap_or(f64::NAN).to_bits(),
            )
        };
        let mut groups: Vec<((u64, u64, u64, u64), Vec<usize>)> = Vec::new();
        for (i, u) in mirror.iter().enumerate() {
            let k = key_of(u);
            match groups.iter_mut().find(|(gk, _)| *gk == k) {
                Some((_, v)) => v.push(i),
                None => groups.push((k, vec![i])),
            }
        }
        assert!(
            warm.alloc_classes <= groups.len(),
            "event {ev}: {} classes from {} distinct specs",
            warm.alloc_classes,
            groups.len()
        );
        for (_, members) in &groups {
            let f = members[0];
            for &i in &members[1..] {
                assert_eq!(
                    warm.g[i].to_bits(),
                    warm.g[f].to_bits(),
                    "event {ev}: class members {f},{i} g diverge: {} vs {}",
                    warm.g[f],
                    warm.g[i]
                );
                assert_eq!(
                    warm.x[i], warm.x[f],
                    "event {ev}: class members {f},{i} split diverges"
                );
                assert_eq!(
                    warm.tasks[i].to_bits(),
                    warm.tasks[f].to_bits(),
                    "event {ev}: class members {f},{i} tasks diverge"
                );
            }
        }

        collapsed_any |= warm.alloc_classes < mirror.len();

        let scratch = allocator::solve(&cluster, &mirror);
        assert_parity(&warm, &scratch, &format!("class-split event {ev}"));
    }
    // the stream must actually have exercised collapse: at some event
    // two users shared an LP variable block
    assert!(collapsed_any, "stream never produced a shared class");
}

/// Generator-driven churn (churn satellite): the same seeded
/// [`drfh::workload::generate_churn`] streams that drive the engine
/// drive the warm allocator here — every `Join` is an `add_user`,
/// every `Leave` a `remove_user`, with the tenant specs drawn from a
/// small demand pool so joins overwhelmingly land in live allocation
/// classes. After every transition the warm allocation must match the
/// from-scratch solve within 1e-9, `lp_vars()` must stay put whenever
/// a join hits an existing class, and the replay as a whole must be
/// cheaper in search pivots than re-solving per event.
#[test]
fn generated_churn_stream_matches_scratch() {
    use drfh::workload::{generate_churn, ChurnGenConfig};
    let demand_pool = [
        ResVec::cpu_mem(0.25, 1.0),
        ResVec::cpu_mem(1.0, 0.25),
        ResVec::cpu_mem(0.5, 0.5),
    ];
    let n = 24usize;
    let spec_of = |u: usize| FluidUser {
        demand: demand_pool[u % demand_pool.len()],
        weight: if u % 4 == 0 { 2.0 } else { 1.0 },
        task_cap: None,
    };
    let cfg = ChurnGenConfig {
        leave_rate: 4e-4,
        rejoin_rate: 1.0 / 900.0,
        absent_frac: 0.25,
        flash_at: Some(2_000.0),
        flash_fraction: 0.3,
        flash_hold: 1_200.0,
        ..ChurnGenConfig::default()
    };
    let horizon = 6_000.0;
    let plan = generate_churn(&cfg, n, horizon, 4242);
    assert!(
        plan.events.len() >= 10,
        "plan too quiet to exercise the warm path: {} events",
        plan.events.len()
    );
    let mut rng = Pcg32::seeded(4242);
    let cluster = Cluster::google_sample(60, &mut rng);
    let mut inc = IncrementalDrfh::new(&cluster);
    // allocation order: insertion order with removals compacting —
    // `ids[p].0` is the trace user occupying position p
    let mut ids: Vec<(usize, UserId)> = Vec::new();
    let mut mirror: Vec<FluidUser> = Vec::new();
    for u in 0..n {
        if !plan.initially_absent(u) {
            ids.push((u, inc.add_user(spec_of(u))));
            mirror.push(spec_of(u));
        }
    }
    inc.allocate();
    let class_key = |u: &FluidUser| {
        (
            u.demand[0].to_bits(),
            u.demand[1].to_bits(),
            u.weight.to_bits(),
        )
    };
    let mut warm_pivots = 0u64;
    let mut scratch_pivots = 0u64;
    let mut joined_live_class = false;
    for (ev, e) in plan.events.iter().enumerate() {
        let pos = ids.iter().position(|&(u, _)| u == e.user);
        if e.join {
            assert!(
                pos.is_none(),
                "event {ev}: canonical plan joined a present user"
            );
            let spec = spec_of(e.user);
            let vars_before = inc.lp_vars();
            let hits_live = mirror
                .iter()
                .any(|m| class_key(m) == class_key(&spec));
            ids.push((e.user, inc.add_user(spec.clone())));
            mirror.push(spec);
            if hits_live {
                joined_live_class = true;
                assert_eq!(
                    inc.lp_vars(),
                    vars_before,
                    "event {ev}: join into a live class resized the LP"
                );
            }
        } else {
            let p = pos.unwrap_or_else(|| {
                panic!("event {ev}: canonical plan left an absent user")
            });
            inc.remove_user(ids.remove(p).1);
            mirror.remove(p);
        }
        if mirror.is_empty() {
            continue;
        }
        let warm = inc.allocate();
        let scratch = allocator::solve(&cluster, &mirror);
        assert_parity(&warm, &scratch, &format!("churn event {ev}"));
        warm_pivots += warm.lp_pivots;
        scratch_pivots += scratch.lp_pivots;
    }
    assert!(
        joined_live_class,
        "no join ever hit a live class — the pool is miswired"
    );
    assert!(
        warm_pivots < scratch_pivots,
        "churn replay not cheaper warm: {warm_pivots} >= {scratch_pivots}"
    );
    let st = inc.solver_stats();
    assert!(st.warm_solves > 0, "warm path never used: {st:?}");
}
