//! Fig. 6 — job completion times: (a) CDF of JCT under Best-Fit DRFH
//! vs Slots over jobs completed in both runs; (b) mean completion-time
//! reduction per job-size bucket.
//!
//! Paper reference: no improvement for small jobs, large reductions for
//! jobs with many tasks (the bigger the job, the bigger the win).

use super::fig5::bestfit_vs_slots_factories;
use super::runner;
use super::{write_csv, EvalSetup};
use crate::metrics::{jct_reduction_by_bucket, JobRecord};
use crate::util::stats;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Fig6Result {
    /// matched (job, bestfit JCT, slots JCT)
    pub matched: Vec<(usize, f64, f64)>,
    /// (bucket label, mean reduction, sample count)
    pub buckets: Vec<(String, f64, usize)>,
    pub bf_jobs: Vec<JobRecord>,
    pub slots_jobs: Vec<JobRecord>,
}

/// Run Best-Fit and Slots on the same setup (in parallel) and match
/// completed jobs.
pub fn run_fig6(setup: &EvalSetup) -> Fig6Result {
    let mut reports = runner::sweep(
        &setup.cluster,
        &setup.trace,
        &setup.opts,
        bestfit_vs_slots_factories(),
    );
    let slots = reports.pop().expect("slots report");
    let bf = reports.pop().expect("best-fit report");
    // order-independent HashMap use: keyed `get` lookups only (the
    // iteration below runs over `bf.jobs`, in record order)
    let by_id: HashMap<usize, &JobRecord> =
        slots.jobs.iter().map(|j| (j.job, j)).collect();
    let matched = bf
        .jobs
        .iter()
        .filter_map(|j| {
            by_id
                .get(&j.job)
                .map(|s| (j.job, j.completion_time(), s.completion_time()))
        })
        .collect();
    let buckets = jct_reduction_by_bucket(&bf.jobs, &slots.jobs);
    Fig6Result { matched, buckets, bf_jobs: bf.jobs, slots_jobs: slots.jobs }
}

pub fn print(res: &Fig6Result) {
    println!("== Fig. 6a: JCT CDF (jobs completed in both runs) ==");
    let bf: Vec<f64> = res.matched.iter().map(|m| m.1).collect();
    let sl: Vec<f64> = res.matched.iter().map(|m| m.2).collect();
    println!("matched jobs: {}", res.matched.len());
    for p in [25.0, 50.0, 75.0, 90.0, 99.0] {
        println!(
            "  p{:<4} best-fit {:>8.0} s   slots {:>8.0} s",
            p,
            stats::percentile(&bf, p),
            stats::percentile(&sl, p)
        );
    }
    println!("== Fig. 6b: mean JCT reduction by job size ==");
    println!("{:<12} {:>12} {:>8}", "tasks/job", "reduction", "jobs");
    for (label, red, count) in &res.buckets {
        println!("{:<12} {:>11.1}% {:>8}", label, red * 100.0, count);
    }
    println!("(paper: ~0% for small jobs, growing with job size)");
    let rows: Vec<String> = res
        .matched
        .iter()
        .map(|(id, b, s)| format!("{id},{b:.1},{s:.1}"))
        .collect();
    write_csv("fig6_jct.csv", "job,bestfit_jct,slots_jct", &rows);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn large_jobs_gain_more_than_small() {
        let setup = EvalSetup::with_duration(17, 120, 12, 12_000.0);
        let res = run_fig6(&setup);
        assert!(
            res.matched.len() > 10,
            "need matched jobs, got {}",
            res.matched.len()
        );
        // aggregate reduction should be positive (DRFH wins overall)
        let mean_red: f64 = res
            .matched
            .iter()
            .map(|(_, b, s)| 1.0 - b / s.max(1e-9))
            .sum::<f64>()
            / res.matched.len() as f64;
        assert!(
            mean_red > 0.0,
            "expected positive mean JCT reduction, got {mean_red:.3}"
        );
    }
}
