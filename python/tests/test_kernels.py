"""Kernel-vs-oracle correctness: the CORE L1/L2 signal.

Hypothesis sweeps shapes, values, masks, and degenerate cases; every
property asserts the Pallas kernels (and the composed L2 graphs) agree
with the pure-jnp oracle in ref.py — allclose on scores, *identical*
argmin decisions (tie-breaking included).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import bestfit, dominant, ref

SET = dict(deadline=None, max_examples=25, print_blob=True)


def rng_for(seed):
    return np.random.default_rng(seed)


# k must be < 128 or a multiple of the 128-wide server tile.
ks = st.one_of(st.integers(1, 127), st.sampled_from([128, 256, 384, 512]))
ns = st.one_of(st.integers(1, 127), st.sampled_from([128, 256]))
ms = st.integers(1, 4)
seeds = st.integers(0, 2**32 - 1)


def random_instance(seed, n, k, m, *, tight=False):
    rng = rng_for(seed)
    avail = rng.uniform(0.0, 1.0, (k, m)).astype(np.float32)
    hi = 1.5 if tight else 0.5  # tight => many infeasible pairs
    demand = rng.uniform(1e-3, hi, (n, m)).astype(np.float32)
    return avail, demand


# ---------------------------------------------------------------- bestfit


@settings(**SET)
@given(seeds, ns, ks, ms, st.booleans())
def test_score_servers_matches_ref(seed, n, k, m, tight):
    avail, demand = random_instance(seed, n, k, m, tight=tight)
    bh_r, bs_r = ref.score_servers(avail, demand)
    bh_p, bs_p = bestfit.score_servers(avail, demand)
    np.testing.assert_allclose(np.asarray(bh_p), np.asarray(bh_r))
    np.testing.assert_array_equal(np.asarray(bs_p), np.asarray(bs_r))


@settings(**SET)
@given(seeds, st.integers(1, 32), st.integers(2, 64), st.integers(1, 3))
def test_score_servers_duplicate_servers_tiebreak(seed, n, k, m):
    """Identical servers => first occurrence must win in both."""
    rng = rng_for(seed)
    row = rng.uniform(0.5, 1.0, (1, m)).astype(np.float32)
    avail = np.repeat(row, k, axis=0)
    demand = rng.uniform(1e-3, 0.4, (n, m)).astype(np.float32)
    _, bs_r = ref.score_servers(avail, demand)
    _, bs_p = bestfit.score_servers(avail, demand)
    np.testing.assert_array_equal(np.asarray(bs_p), np.asarray(bs_r))
    # every feasible user must pick server 0 (first of the duplicates)
    feasible = (avail[0][None, :] >= demand).all(axis=1)
    np.testing.assert_array_equal(
        np.asarray(bs_p)[feasible], np.zeros(feasible.sum(), np.int32)
    )


def test_score_servers_zero_avail_rows():
    """Fully-drained servers are infeasible, not NaN/crash."""
    avail = np.array([[0.0, 0.0], [0.5, 0.5]], np.float32)
    demand = np.array([[0.1, 0.1]], np.float32)
    bh, bs = bestfit.score_servers(avail, demand)
    assert np.isfinite(np.asarray(bh)).all()
    assert int(np.asarray(bs)[0]) == 1


def test_score_servers_nothing_fits():
    avail = np.full((4, 2), 0.01, np.float32)
    demand = np.full((3, 2), 0.5, np.float32)
    bh, bs = bestfit.score_servers(avail, demand)
    assert np.isinf(np.asarray(bh)).all()
    assert (np.asarray(bs) == -1).all()


@settings(**SET)
@given(seeds, st.integers(1, 8), st.sampled_from([128, 256]), st.integers(1, 3))
def test_score_servers_cross_tile_tiebreak(seed, n, k, m):
    """Ties spanning tile boundaries resolve to the lowest index."""
    rng = rng_for(seed)
    row = rng.uniform(0.5, 1.0, (1, m)).astype(np.float32)
    avail = np.repeat(row, k, axis=0)  # every tile identical
    demand = rng.uniform(1e-3, 0.4, (n, m)).astype(np.float32)
    _, bs_p = bestfit.score_servers(avail, demand)
    feasible = (avail[0][None, :] >= demand).all(axis=1)
    assert (np.asarray(bs_p)[feasible] == 0).all()


# --------------------------------------------------------------- dominant


@settings(**SET)
@given(seeds, ns)
def test_select_user_matches_ref(seed, n):
    rng = rng_for(seed)
    share = rng.uniform(0, 1, n).astype(np.float32)
    weight = rng.uniform(0.1, 4.0, n).astype(np.float32)
    mask = (rng.uniform(0, 1, n) > 0.4).astype(np.int32)
    u_r = ref.select_user(share, weight, mask != 0)
    u_p = dominant.select_user(share, weight, mask)
    assert int(u_r) == int(np.asarray(u_p)[0])


@settings(**SET)
@given(seeds, ns)
def test_select_user_empty_mask(seed, n):
    rng = rng_for(seed)
    share = rng.uniform(0, 1, n).astype(np.float32)
    weight = np.ones(n, np.float32)
    mask = np.zeros(n, np.int32)
    assert int(np.asarray(dominant.select_user(share, weight, mask))[0]) == -1


@settings(**SET)
@given(seeds, st.sampled_from([128, 256]))
def test_select_user_all_ties(seed, n):
    """All-equal shares => lowest eligible index wins."""
    rng = rng_for(seed)
    share = np.full(n, 0.25, np.float32)
    weight = np.ones(n, np.float32)
    mask = (rng.uniform(0, 1, n) > 0.5).astype(np.int32)
    u = int(np.asarray(dominant.select_user(share, weight, mask))[0])
    expect = int(np.flatnonzero(mask)[0]) if mask.any() else -1
    assert u == expect


# ------------------------------------------------------------------ model


@settings(**SET)
@given(seeds, ns, ks, ms)
def test_sched_step_matches_ref(seed, n, k, m):
    avail, demand = random_instance(seed, n, k, m)
    rng = rng_for(seed + 1)
    share = rng.uniform(0, 1, n).astype(np.float32)
    weight = rng.uniform(0.5, 2.0, n).astype(np.float32)
    active = (rng.uniform(0, 1, n) > 0.3).astype(np.int32)
    u_r, s_r = ref.sched_step(avail, demand, share, weight, active != 0)
    u_p, s_p = model.sched_step(avail, demand, share, weight, active)
    assert (int(u_r), int(s_r)) == (int(u_p[0]), int(s_p[0]))


@settings(deadline=None, max_examples=10)
@given(seeds, st.integers(2, 24), st.integers(4, 100), st.integers(1, 3),
       st.integers(1, 48))
def test_sched_loop_matches_ref(seed, n, k, m, steps):
    avail, demand = random_instance(seed, n, k, m)
    rng = rng_for(seed + 2)
    share = np.zeros(n, np.float32)
    weight = rng.uniform(0.5, 2.0, n).astype(np.float32)
    pending = rng.integers(0, 6, n).astype(np.int32)
    dec_r, av_r, sh_r, pe_r = ref.sched_loop(
        avail, demand, share, weight, pending, steps
    )
    dec_p, av_p, sh_p, pe_p = model.sched_loop(
        avail, demand, share, weight, pending, steps=steps
    )
    np.testing.assert_array_equal(np.asarray(dec_p), np.asarray(dec_r))
    np.testing.assert_allclose(np.asarray(av_p), np.asarray(av_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sh_p), np.asarray(sh_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(pe_p), np.asarray(pe_r))


@settings(deadline=None, max_examples=10)
@given(seeds, st.integers(2, 16), st.integers(4, 64), st.integers(1, 3))
def test_sched_loop_conservation(seed, n, k, m):
    """Resources removed from avail == sum of placed task demands,
    pending decrements match placements, shares grow by dominant demand."""
    avail, demand = random_instance(seed, n, k, m)
    rng = rng_for(seed + 3)
    weight = np.ones(n, np.float32)
    pending = rng.integers(0, 8, n).astype(np.int32)
    steps = 32
    dec, av, sh, pe = model.sched_loop(
        avail, demand, np.zeros(n, np.float32), weight, pending, steps=steps
    )
    dec = np.asarray(dec)
    placed = dec[dec[:, 0] >= 0]
    counts = np.bincount(placed[:, 0], minlength=n)
    np.testing.assert_array_equal(np.asarray(pe), pending - counts)
    expected_av = avail.copy()
    for u, s in placed:
        expected_av[s] -= demand[u]
    np.testing.assert_allclose(np.asarray(av), expected_av, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(sh), counts * demand.max(axis=1), rtol=1e-5, atol=1e-6
    )
    # placements only stop being made if nothing fits or nothing pending
    if (dec[:, 0] == -1).any() and (np.asarray(pe) > 0).any():
        bh, _ = ref.score_servers(np.asarray(av), demand)
        assert not np.isfinite(
            np.asarray(bh)[np.asarray(pe) > 0]
        ).any(), "loop stalled while a feasible placement existed"


def test_sched_loop_no_pending_is_noop():
    avail = np.ones((4, 2), np.float32)
    demand = np.full((3, 2), 0.2, np.float32)
    dec, av, sh, pe = model.sched_loop(
        avail, demand, np.zeros(3, np.float32), np.ones(3, np.float32),
        np.zeros(3, np.int32), steps=8
    )
    assert (np.asarray(dec) == -1).all()
    np.testing.assert_array_equal(np.asarray(av), avail)


def test_paper_fig1_example_decision():
    """Fig. 1 instance: mem-heavy user 1 must be routed to the
    high-memory server, CPU-heavy user 2 to the high-CPU server."""
    # server 1: 2 CPU 12 GB; server 2: 12 CPU 2 GB
    avail = np.array([[2.0, 12.0], [12.0, 2.0]], np.float32)
    demand = np.array([[0.2, 1.0], [1.0, 0.2]], np.float32)
    _, bs = bestfit.score_servers(avail, demand)
    assert list(np.asarray(bs)) == [0, 1]
