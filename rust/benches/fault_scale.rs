//! §Perf + robustness harness for the fault-injection layer: the
//! Fig. 5 Best-Fit configuration at k = 2,000 servers under a
//! crash-rate × retry-policy sweep, on the wheel + streaming data
//! plane.
//!
//! Measured per cell: wall time, goodput / wasted service hours,
//! evictions, retries, lost tasks, and fairness-recovery latency.
//! Alongside the sweep the bench enforces the two replay guarantees
//! cheaply (the bit-exact proofs live in `tests/engine_parity.rs`):
//!
//! * `FaultPlan::none()` parity — the no-fault run matches the
//!   pre-fault engine's counts at 1 shard and at the core count;
//! * seeded replay — the same plan + seed reproduces goodput and
//!   wasted-work floats bit-for-bit, sharded or not.
//!
//! Results go to `BENCH_faults.json` at the repo root (override with
//! `BENCH_OUT=/path.json`); CI runs the small-scale smoke via
//! `FAULT_SMOKE=1`.
//!
//! Run: `cargo bench --bench fault_scale`

use drfh::experiments::EvalSetup;
use drfh::metrics::MetricsMode;
use drfh::sched::BestFitDrfh;
use drfh::sim::{
    run, FaultPlan, QueueKind, RetryPolicy, ShardCount, SimOpts, SimReport,
};
use drfh::util::bench::{bench_n, header, write_suite_json, BenchResult};
use drfh::util::json::Json;
use drfh::workload::{generate_faults, FaultGenConfig};

struct Case {
    bench: BenchResult,
    report: SimReport,
}

fn run_case(
    name: &str,
    setup: &EvalSetup,
    plan: &FaultPlan,
    retry: RetryPolicy,
    shards: usize,
) -> Case {
    let mut report = None;
    let bench = bench_n(name, 1, || {
        let opts = SimOpts {
            queue: QueueKind::Wheel,
            metrics: MetricsMode::streaming(),
            shards: ShardCount::Fixed(shards),
            faults: plan.clone(),
            retry,
            ..setup.opts.clone()
        };
        let rep = run(
            setup.cluster.clone(),
            &setup.trace,
            Box::new(BestFitDrfh::default()),
            opts,
        );
        let placed = rep.tasks_placed;
        report = Some(rep);
        placed
    });
    Case { bench, report: report.expect("bench ran at least once") }
}

fn mean_recovery(rep: &SimReport) -> f64 {
    let times: Vec<f64> =
        rep.outages.iter().filter_map(|o| o.recovery_time()).collect();
    if times.is_empty() {
        0.0
    } else {
        times.iter().sum::<f64>() / times.len() as f64
    }
}

fn main() {
    let smoke = std::env::var_os("FAULT_SMOKE").is_some();
    let (servers, users, duration) = if smoke {
        (200usize, 20usize, 3_600.0f64)
    } else {
        (2_000, 100, 32_400.0)
    };
    let setup = EvalSetup::with_duration(2024, servers, users, duration);
    let offered = setup.trace.total_tasks();
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    println!(
        "fault_scale: k={servers} n={users} horizon={duration:.0}s \
         ({offered} tasks offered, {hw} cores){}",
        if smoke { " [smoke]" } else { "" }
    );

    // ---- replay guards first: none-plan parity and seeded replay
    header("fault_scale: replay guards");
    let none = FaultPlan::none();
    let baseline =
        run_case("none-s1", &setup, &none, RetryPolicy::default(), 1);
    let baseline_sharded =
        run_case("none-shw", &setup, &none, RetryPolicy::default(), hw);
    assert_eq!(
        baseline.report.tasks_placed, baseline_sharded.report.tasks_placed,
        "FaultPlan::none() parity: placement counts diverged across shards"
    );
    assert_eq!(
        baseline.report.job_stats, baseline_sharded.report.job_stats,
        "FaultPlan::none() parity: job stats diverged across shards"
    );
    assert_eq!(baseline.report.evictions, 0);
    assert_eq!(baseline.report.wasted_s, 0.0);
    assert!(baseline.report.outages.is_empty());

    let guard_cfg = FaultGenConfig {
        crash_rate: if smoke { 2e-5 } else { 2e-6 },
        mean_downtime: 1_800.0,
        flash_at: Some(duration / 3.0),
        flash_fraction: 0.2,
        flash_downtime: 1_800.0,
        ..FaultGenConfig::default()
    };
    let guard_plan =
        generate_faults(&guard_cfg, servers, duration, setup.seed);
    let replay_a =
        run_case("replay-a", &setup, &guard_plan, RetryPolicy::default(), 1);
    let replay_b =
        run_case("replay-b", &setup, &guard_plan, RetryPolicy::default(), 1);
    let replay_s =
        run_case("replay-shw", &setup, &guard_plan, RetryPolicy::default(), hw);
    for (label, r) in
        [("same-seed rerun", &replay_b), ("sharded rerun", &replay_s)]
    {
        assert_eq!(
            replay_a.report.goodput_s.to_bits(),
            r.report.goodput_s.to_bits(),
            "{label}: goodput not bit-identical"
        );
        assert_eq!(
            replay_a.report.wasted_s.to_bits(),
            r.report.wasted_s.to_bits(),
            "{label}: wasted work not bit-identical"
        );
        assert_eq!(
            (
                replay_a.report.tasks_placed,
                replay_a.report.evictions,
                replay_a.report.retries,
                replay_a.report.tasks_lost,
            ),
            (
                r.report.tasks_placed,
                r.report.evictions,
                r.report.retries,
                r.report.tasks_lost,
            ),
            "{label}: counters diverged"
        );
        assert_eq!(
            replay_a.report.outages, r.report.outages,
            "{label}: outage records diverged"
        );
    }
    assert!(
        replay_a.report.evictions > 0,
        "guard plan evicted nothing — the sweep below would be vacuous"
    );
    println!(
        "guards ok: none-plan parity at S=1/{hw}, seeded replay \
         bit-identical ({} evictions)",
        replay_a.report.evictions
    );

    // ---- the sweep: crash rate x retry policy
    let crash_rates: &[f64] = if smoke {
        &[1e-5, 4e-5]
    } else {
        &[1e-6, 4e-6]
    };
    let policies: &[(&str, RetryPolicy)] = &[
        ("no-retry", RetryPolicy { max_attempts: 1, ..RetryPolicy::default() }),
        ("default", RetryPolicy::default()),
        (
            "eager",
            RetryPolicy {
                max_attempts: 6,
                base: 5.0,
                cap: 600.0,
                jitter: 0.5,
            },
        ),
    ];
    header("fault_scale: crash rate x retry policy (Best-Fit, sharded)");
    println!(
        "{:<22} {:>9} {:>11} {:>10} {:>7} {:>7} {:>6} {:>10}",
        "case", "outages", "goodput h", "wasted h", "evict", "retry",
        "lost", "mean rec s"
    );
    let mut cells: Vec<(String, f64, Case)> = Vec::new();
    for &rate in crash_rates {
        let cfg = FaultGenConfig {
            crash_rate: rate,
            mean_downtime: 1_800.0,
            ..FaultGenConfig::default()
        };
        let plan = generate_faults(&cfg, servers, duration, setup.seed);
        for (pname, policy) in policies {
            let name = format!("crash-{rate:.0e}-{pname}");
            let case = run_case(&name, &setup, &plan, *policy, hw);
            let r = &case.report;
            println!(
                "{:<22} {:>9} {:>11.1} {:>10.1} {:>7} {:>7} {:>6} {:>10.0}",
                name,
                r.outages.len(),
                r.goodput_s / 3600.0,
                r.wasted_s / 3600.0,
                r.evictions,
                r.retries,
                r.tasks_lost,
                mean_recovery(r),
            );
            cells.push((name, rate, case));
        }
    }

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_faults.json")
            .to_string()
    });
    let mut meta: Vec<(String, Json)> = vec![
        ("servers".to_string(), Json::Num(servers as f64)),
        ("users".to_string(), Json::Num(users as f64)),
        ("horizon_s".to_string(), Json::Num(duration)),
        ("tasks_offered".to_string(), Json::Num(offered as f64)),
        ("smoke".to_string(), Json::Bool(smoke)),
        ("cores".to_string(), Json::Num(hw as f64)),
        (
            "guard_evictions".to_string(),
            Json::Num(replay_a.report.evictions as f64),
        ),
        (
            "baseline_goodput_s".to_string(),
            Json::Num(baseline.report.goodput_s),
        ),
    ];
    for (name, rate, case) in &cells {
        let r = &case.report;
        meta.push((format!("{name}_crash_rate"), Json::Num(*rate)));
        meta.push((format!("{name}_goodput_s"), Json::Num(r.goodput_s)));
        meta.push((format!("{name}_wasted_s"), Json::Num(r.wasted_s)));
        meta.push((
            format!("{name}_evictions"),
            Json::Num(r.evictions as f64),
        ));
        meta.push((format!("{name}_retries"), Json::Num(r.retries as f64)));
        meta.push((
            format!("{name}_tasks_lost"),
            Json::Num(r.tasks_lost as f64),
        ));
        meta.push((
            format!("{name}_mean_recovery_s"),
            Json::Num(mean_recovery(r)),
        ));
    }
    let meta_refs: Vec<(&str, Json)> =
        meta.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let mut results = vec![
        baseline.bench,
        baseline_sharded.bench,
        replay_a.bench,
        replay_b.bench,
        replay_s.bench,
    ];
    results.extend(cells.into_iter().map(|(_, _, c)| c.bench));
    let path = std::path::PathBuf::from(&out);
    if write_suite_json(&path, "fault_scale", &meta_refs, &results) {
        println!("\nwrote {}", path.display());
    } else {
        println!("\ncould not write {} (read-only fs?)", path.display());
    }
}
