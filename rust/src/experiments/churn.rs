//! Churn experiment — dynamic user churn end to end: the same
//! generated join/leave plan drives (a) the discrete-event engine
//! (Best-Fit DRFH under churn vs the churn-free control, flash-crowd
//! share trajectories) and (b) the incremental fluid allocator, where
//! each transition is applied warm ([`IncrementalDrfh::add_user`] /
//! [`IncrementalDrfh::remove_user`]) and compared against re-solving
//! the LP from scratch — the measured pivot savings are the point of
//! the standing-LP design (ROADMAP §fluid allocator).
//!
//! The engine pair shares one trace, so every difference in completed
//! work is the churn plan's; the fluid replay checks its warm
//! allocation against [`crate::allocator::solve`] at every event
//! (`parity_ok`), so the savings are of bit-trustworthy solves.

use super::{runner, write_csv, EvalSetup};
use crate::allocator::{self, incremental::UserId, FluidUser, IncrementalDrfh};
use crate::sched::{BestFitDrfh, Scheduler};
use crate::sim::{run, SimReport};
use crate::workload::{generate_churn, ChurnGenConfig};

/// Reports for the churn comparison plus the fluid replay account.
#[derive(Clone, Debug)]
pub struct ChurnResult {
    /// Best-Fit DRFH with no churn injected (the control run).
    pub baseline: SimReport,
    /// Best-Fit DRFH under the churn plan (user share series tracked).
    pub churned: SimReport,
    /// Join/leave transitions in the compiled plan.
    pub plan_events: usize,
    /// Users absent when the trace starts.
    pub initially_absent: usize,
    /// Cohort size of the one-off flash crowd (0 = no flash).
    pub flash_joins: usize,
    /// Search pivots the warm allocator spent replaying the plan
    /// (excluding the initial build).
    pub warm_pivots: u64,
    /// Search pivots the same replay costs when every event re-solves
    /// the LP from scratch.
    pub scratch_pivots: u64,
    /// Max |warm − scratch| dominant-share error across every event.
    pub max_g_err: f64,
    /// `(t, mean incumbent share, mean flash-cohort share)` at the
    /// sample ticks around the flash instant.
    pub flash_recovery: Vec<(f64, f64, f64)>,
}

impl ChurnResult {
    /// Did the warm allocation match the from-scratch reference at
    /// every replayed event?
    pub fn parity_ok(&self) -> bool {
        self.max_g_err <= 1e-9
    }

    /// Fraction of the scratch pivots the warm path avoided.
    pub fn pivot_savings(&self) -> f64 {
        if self.scratch_pivots == 0 {
            return 0.0;
        }
        1.0 - self.warm_pivots as f64 / self.scratch_pivots as f64
    }
}

/// The default churn mix for `drfh exp churn`: a third of the tenants
/// start absent, everyone churns on a slow diurnally-modulated renewal
/// process, and a flash crowd of a quarter of the population joins at
/// once a third of the way in, holding for an eighth of the horizon.
pub fn default_churn_config(horizon: f64) -> ChurnGenConfig {
    ChurnGenConfig {
        leave_rate: 5e-5,
        absent_frac: 0.3,
        flash_at: Some(horizon / 3.0),
        flash_fraction: 0.25,
        flash_hold: horizon / 8.0,
        diurnal_amp: 0.5,
        ..ChurnGenConfig::default()
    }
}

/// Run the comparison: compile the plan from `cfg`, replay it in the
/// engine (against the churn-free control) and through the warm fluid
/// allocator (against per-event from-scratch solves).
pub fn run_churn(setup: &EvalSetup, cfg: &ChurnGenConfig) -> ChurnResult {
    let plan = generate_churn(
        cfg,
        setup.trace.users.len(),
        setup.opts.horizon,
        setup.seed,
    );
    let plan_events = plan.events.len();
    let initially_absent = plan.absent_at_start.len();
    let flash_at = cfg.flash_at;
    let flash_cohort: Vec<usize> = match flash_at {
        Some(at) => plan
            .events
            .iter()
            .filter(|e| e.join && e.time == at)
            .map(|e| e.user)
            .collect(),
        None => Vec::new(),
    };

    // engine pair: one trace, with and without the plan (two
    // independent jobs — fan them out like the policy sweeps do)
    let mut churn_opts = setup.opts.clone();
    churn_opts.churn = plan.clone();
    churn_opts.track_user_series = true;
    let jobs: Vec<runner::Job<'_, SimReport>> = vec![
        Box::new(|| {
            let sched: Box<dyn Scheduler> = Box::new(BestFitDrfh::default());
            run(setup.cluster.clone(), &setup.trace, sched, setup.opts.clone())
        }),
        Box::new(|| {
            let sched: Box<dyn Scheduler> = Box::new(BestFitDrfh::default());
            run(setup.cluster.clone(), &setup.trace, sched, churn_opts.clone())
        }),
    ];
    let mut reports = runner::run_parallel(jobs);
    let churned = reports.pop().expect("churned report");
    let baseline = reports.pop().expect("baseline report");

    // fluid replay: warm add/remove per transition vs a from-scratch
    // solve of the same population, with pivot accounting for both
    let fluid_user = |u: usize| {
        let spec = &setup.trace.users[u];
        FluidUser { demand: spec.demand, weight: spec.weight, task_cap: None }
    };
    let mut inc = IncrementalDrfh::new(&setup.cluster);
    let mut ids: Vec<Option<UserId>> =
        vec![None; setup.trace.users.len()];
    for u in 0..setup.trace.users.len() {
        if !plan.initially_absent(u) {
            ids[u] = Some(inc.add_user(fluid_user(u)));
        }
    }
    inc.allocate();
    let base_pivots = inc.solver_stats().pivots;
    let mut scratch_pivots = 0u64;
    let mut max_g_err = 0.0f64;
    for ev in &plan.events {
        match (ev.join, ids[ev.user]) {
            (true, None) => ids[ev.user] = Some(inc.add_user(fluid_user(ev.user))),
            (false, Some(id)) => {
                inc.remove_user(id);
                ids[ev.user] = None;
            }
            // `ChurnPlan::from_transitions` drops redundant
            // transitions, so these arms never fire on generated plans
            _ => continue,
        }
        let warm = inc.allocate();
        let specs = inc.users();
        let reference = allocator::solve(&setup.cluster, &specs);
        for (a, b) in warm.g.iter().zip(&reference.g) {
            max_g_err = max_g_err.max((a - b).abs());
        }
        let mut scratch = IncrementalDrfh::new(&setup.cluster);
        for spec in specs {
            scratch.add_user(spec);
        }
        scratch.allocate();
        scratch_pivots += scratch.solver_stats().pivots;
    }
    let warm_pivots = inc.solver_stats().pivots - base_pivots;

    // flash-crowd share trajectories: cohort vs incumbents around the
    // flash instant, off the tracked per-user dominant-share series
    let mut flash_recovery = Vec::new();
    if let (Some(at), false, false) = (
        flash_at,
        flash_cohort.is_empty(),
        churned.user_dom_share.is_empty(),
    ) {
        let mut in_cohort = vec![false; churned.user_dom_share.len()];
        for &u in &flash_cohort {
            in_cohort[u] = true;
        }
        let dt = setup.opts.sample_dt;
        let grid = &churned.user_dom_share[0].t;
        for (i, &t) in grid.iter().enumerate() {
            if t < at - 4.0 * dt || t > at + 16.0 * dt {
                continue;
            }
            let (mut cs, mut cn, mut is, mut inn) = (0.0, 0usize, 0.0, 0usize);
            for (u, series) in churned.user_dom_share.iter().enumerate() {
                let v = series.v[i];
                if in_cohort[u] {
                    cs += v;
                    cn += 1;
                } else {
                    is += v;
                    inn += 1;
                }
            }
            flash_recovery.push((
                t,
                if inn > 0 { is / inn as f64 } else { 0.0 },
                if cn > 0 { cs / cn as f64 } else { 0.0 },
            ));
        }
    }

    ChurnResult {
        baseline,
        churned,
        plan_events,
        initially_absent,
        flash_joins: flash_cohort.len(),
        warm_pivots,
        scratch_pivots,
        max_g_err,
        flash_recovery,
    }
}

pub fn print(res: &ChurnResult) {
    println!("== Churn: joins/leaves, warm-start savings, flash crowd ==");
    println!(
        "(plan: {} transitions, {} users absent at start, flash cohort {})",
        res.plan_events, res.initially_absent, res.flash_joins
    );
    println!(
        "{:<18} {:>7} {:>7} {:>10} {:>11} {:>11}",
        "run", "joins", "leaves", "abandoned", "tasks done", "goodput h"
    );
    for (label, r) in
        [("bestfit (clean)", &res.baseline), ("bestfit", &res.churned)]
    {
        println!(
            "{:<18} {:>7} {:>7} {:>10} {:>11} {:>11.1}",
            label,
            r.user_joins,
            r.user_leaves,
            r.tasks_abandoned,
            r.tasks_completed,
            r.goodput_s / 3600.0,
        );
    }
    println!(
        "fluid replay: warm {} pivots vs scratch {} ({:.1}% saved), \
         max dominant-share error {:.2e} ({})",
        res.warm_pivots,
        res.scratch_pivots,
        res.pivot_savings() * 100.0,
        res.max_g_err,
        if res.parity_ok() { "parity ok" } else { "PARITY FAILURE" }
    );
    if let Some((t0, _, c0)) = res.flash_recovery.first() {
        let (t1, _, c1) =
            res.flash_recovery.last().expect("non-empty window");
        println!(
            "flash crowd: cohort mean share {:.4} at t={:.0} -> {:.4} \
             at t={:.0} over {} sample ticks",
            c0,
            t0,
            c1,
            t1,
            res.flash_recovery.len()
        );
    }
    let rows: Vec<String> = res
        .flash_recovery
        .iter()
        .map(|(t, inc, coh)| format!("{t:.1},{inc:.6},{coh:.6}"))
        .collect();
    write_csv(
        "churn_flash_shares.csv",
        "t,incumbent_mean_share,flash_mean_share",
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_run_replays_warm_and_saves_pivots() {
        let setup = EvalSetup::with_duration(17, 40, 8, 6_000.0);
        let cfg = ChurnGenConfig {
            leave_rate: 2e-4,
            rejoin_rate: 1.0 / 600.0,
            absent_frac: 0.25,
            flash_at: Some(2_000.0),
            flash_fraction: 0.5,
            flash_hold: 1_000.0,
            ..ChurnGenConfig::default()
        };
        let res = run_churn(&setup, &cfg);

        // the plan actually churns, and the engine applied it
        assert!(res.plan_events > 0);
        assert!(res.churned.user_joins > 0, "no joins applied");
        assert!(res.churned.user_leaves > 0, "no leaves applied");
        // the control run injects nothing
        assert_eq!(res.baseline.user_joins, 0);
        assert_eq!(res.baseline.user_leaves, 0);
        assert_eq!(res.baseline.tasks_abandoned, 0);
        assert_eq!(res.baseline.abandoned_s, 0.0);

        // warm replay matches the from-scratch reference at every
        // event, and is cheaper than re-solving every time
        assert!(res.parity_ok(), "max g err {}", res.max_g_err);
        assert!(
            res.warm_pivots < res.scratch_pivots,
            "warm {} >= scratch {}",
            res.warm_pivots,
            res.scratch_pivots
        );

        // the flash crowd fired and its trajectory was captured
        assert!(res.flash_joins > 0, "empty flash cohort");
        assert!(
            !res.flash_recovery.is_empty(),
            "no sample ticks in the flash window"
        );
    }
}
