//! Contiguous server-pool partitioning for the sharded data plane.
//!
//! The paper's placement step (Sec. V-B, Algorithm 1) is per-server:
//! feasibility and the Best-Fit H-score of server `l` depend on `l`'s
//! own capacity and usage alone, which is why the PS-DSF line of work
//! (Khamse-Ashari et al., 2017) can decompose scheduling per server
//! without changing the mechanism. The engine exploits the same
//! structure by splitting the pool into `S` *contiguous* shards: each
//! shard owns its servers' processor-sharing state and event lane
//! (`sim::engine` §Perf: sharded data plane), and the placement index
//! keeps per-shard heaps reconciled by a cross-shard argmin
//! (`sched::index::PlacementIndex`).
//!
//! Shards are contiguous index ranges so slices of per-server columns
//! (`Vec<Server>`, the engine's `Vec<ServerSim>`) can be handed to
//! scoped worker threads via `split_at_mut` — no index indirection on
//! the hot path, and `owner_of` is O(1) arithmetic. The partition is
//! *semantics-free*: every consumer reconciles shard-local results in
//! the same total order the unsharded structure uses, so any shard
//! count yields bit-identical decisions (`tests/engine_parity.rs`).

/// How many shards to split the server pool into
/// (`sim::SimOpts::shards` / the `[sim] shards` config key).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardCount {
    /// One shard per available core
    /// ([`std::thread::available_parallelism`]).
    Auto,
    /// Exactly `n` shards (clamped to `[1, k]` at resolution).
    Fixed(usize),
}

impl Default for ShardCount {
    fn default() -> Self {
        ShardCount::Fixed(1)
    }
}

impl ShardCount {
    /// Resolve to a concrete shard count for a `k`-server pool:
    /// `Auto` = available cores; always at least 1 and at most `k`
    /// (an empty shard buys nothing).
    pub fn resolve(&self, k: usize) -> usize {
        let raw = match self {
            ShardCount::Auto => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            ShardCount::Fixed(n) => *n,
        };
        raw.clamp(1, k.max(1))
    }
}

/// A balanced contiguous partition of servers `0..k` into `shards`
/// ranges: the first `k % shards` shards hold `⌈k/shards⌉` servers,
/// the rest `⌊k/shards⌋`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    k: usize,
    shards: usize,
    /// Base shard size (`k / shards`).
    q: usize,
    /// Shards `0..rem` hold one extra server.
    rem: usize,
}

impl ShardSpec {
    /// Partition `k` servers into `shards` contiguous ranges (clamped
    /// to `[1, k]` like [`ShardCount::resolve`]).
    pub fn contiguous(k: usize, shards: usize) -> Self {
        let shards = shards.clamp(1, k.max(1));
        ShardSpec { k, shards, q: k / shards, rem: k % shards }
    }

    /// Number of shards.
    #[inline]
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Number of servers partitioned.
    #[inline]
    pub fn servers(&self) -> usize {
        self.k
    }

    /// First server index of shard `s`.
    #[inline]
    pub fn start_of(&self, s: usize) -> usize {
        debug_assert!(s <= self.shards);
        s * self.q + s.min(self.rem)
    }

    /// Number of servers in shard `s`.
    #[inline]
    pub fn len_of(&self, s: usize) -> usize {
        debug_assert!(s < self.shards);
        self.q + usize::from(s < self.rem)
    }

    /// Server-index range owned by shard `s`.
    #[inline]
    pub fn range(&self, s: usize) -> std::ops::Range<usize> {
        let lo = self.start_of(s);
        lo..lo + self.len_of(s)
    }

    /// The shard owning `server` — O(1) (the inverse of the balanced
    /// layout: big shards first, then base-sized ones).
    #[inline]
    pub fn owner_of(&self, server: usize) -> usize {
        debug_assert!(server < self.k);
        let big = self.rem * (self.q + 1);
        if server < big {
            server / (self.q + 1)
        } else {
            self.rem + (server - big) / self.q.max(1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_clamps_to_pool_size() {
        assert_eq!(ShardCount::Fixed(1).resolve(2000), 1);
        assert_eq!(ShardCount::Fixed(8).resolve(2000), 8);
        assert_eq!(ShardCount::Fixed(0).resolve(2000), 1);
        assert_eq!(ShardCount::Fixed(64).resolve(3), 3);
        assert!(ShardCount::Auto.resolve(2000) >= 1);
        assert!(ShardCount::Auto.resolve(2) <= 2);
        assert_eq!(ShardCount::default(), ShardCount::Fixed(1));
    }

    #[test]
    fn contiguous_partition_covers_the_pool_exactly() {
        for (k, s) in [(10, 3), (2000, 8), (7, 7), (5, 1), (3, 64), (1, 1)] {
            let spec = ShardSpec::contiguous(k, s);
            assert_eq!(spec.servers(), k);
            assert!(spec.shards() >= 1 && spec.shards() <= k);
            let mut covered = 0;
            for sh in 0..spec.shards() {
                let r = spec.range(sh);
                assert_eq!(r.start, covered, "shard {sh} not contiguous");
                assert_eq!(r.len(), spec.len_of(sh));
                covered = r.end;
            }
            assert_eq!(covered, k, "partition must cover all servers");
            // balanced: sizes differ by at most one
            let sizes: Vec<usize> =
                (0..spec.shards()).map(|sh| spec.len_of(sh)).collect();
            let (lo, hi) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            assert!(hi - lo <= 1, "unbalanced partition {sizes:?}");
        }
    }

    #[test]
    fn owner_of_inverts_the_ranges() {
        for (k, s) in [(10, 3), (2000, 8), (12_583, 16), (9, 9), (4, 2)] {
            let spec = ShardSpec::contiguous(k, s);
            for sh in 0..spec.shards() {
                for l in spec.range(sh) {
                    assert_eq!(
                        spec.owner_of(l),
                        sh,
                        "server {l} of {k} across {s} shards"
                    );
                }
            }
        }
    }
}
