//! §Perf diagnostic for the class-keyed scheduler state
//! (`drfh exp user-scale`): run the same Best-Fit DRFH simulation on
//! the class-keyed path (the default) and on the PR 1 per-user index
//! layout, assert the two runs are *bit-identical* (full
//! [`SimReport`] equality — every decision feeds every derived
//! float), and report throughput and per-event cost.
//!
//! This is the `exp`-level smoke path for `benches/user_scale.rs`:
//! the bench produces the committed `BENCH_users.json` sweep
//! (users 10³ → 10⁶ at ~10 demand classes, k = 2000); this harness
//! runs at whatever scale the CLI asks for
//! (`--servers/--users/--duration`) and is cheap enough for tests.

use crate::cluster::{Cluster, ResVec};
use crate::sched::BestFitDrfh;
use crate::sim::{run, SimOpts, SimReport};
use crate::util::Pcg32;
use crate::workload::{JobSpec, TaskSpec, Trace, UserSpec};
use std::time::{Duration, Instant};

/// Demand classes the synthetic workload draws from (the sweep's
/// fixed class count).
pub const DEFAULT_CLASSES: usize = 10;

/// Build a trace whose `n_users` users share exactly
/// `min(n_classes, n_users)` distinct demand rows and a small cycle
/// of weights (including a zero-weight cohort, exercising the guarded
/// `effective_weight` semantics), offering ~`total_tasks` tasks over
/// `duration` seconds.
///
/// This is the workload shape the class-keyed state is built for —
/// [`crate::workload::DemandTable`] interns the rows at build, so
/// per-event scheduler work depends on the class count while the
/// user count scales freely. Deterministic in `seed`.
pub fn classed_trace(
    n_users: usize,
    n_classes: usize,
    total_tasks: usize,
    duration: f64,
    seed: u64,
) -> Trace {
    assert!(n_users > 0 && duration > 0.0);
    let n_classes = n_classes.clamp(1, n_users);
    let mut rng = Pcg32::new(seed, 0x5eed_c1a5);
    // distinct demand rows spanning CPU-heavy / mem-heavy / balanced
    // profiles; the formula keys every component on `c`, so rows are
    // pairwise bit-distinct
    let rows: Vec<ResVec> = (0..n_classes)
        .map(|c| {
            let frac = (c as f64 + 1.0) / (n_classes as f64 + 1.0);
            let dom = 0.04 + 0.28 * frac;
            let skew = 0.2 + 0.6 * frac;
            match c % 3 {
                0 => ResVec::cpu_mem(dom, dom * skew),
                1 => ResVec::cpu_mem(dom * skew, dom),
                _ => ResVec::cpu_mem(dom, dom * 0.9),
            }
        })
        .collect();
    const WEIGHTS: [f64; 4] = [1.0, 2.0, 0.5, 0.0];
    let users: Vec<UserSpec> = (0..n_users)
        .map(|u| UserSpec {
            demand: rows[u % n_classes],
            weight: WEIGHTS[(u / n_classes) % WEIGHTS.len()],
        })
        .collect();
    // jobs spread uniformly over the trace, a few tasks each (mean 4)
    let n_jobs = (total_tasks / 4).max(1);
    let mut jobs: Vec<JobSpec> = (0..n_jobs)
        .map(|_| {
            let user = rng.below(n_users);
            let submit = rng.uniform(0.0, duration);
            let ntasks = 1 + rng.below(7);
            let tasks = (0..ntasks)
                .map(|_| TaskSpec {
                    duration: rng.pareto_bounded(30.0, 3_600.0, 1.3),
                })
                .collect();
            JobSpec { id: 0, user, submit, tasks }
        })
        .collect();
    jobs.sort_by(|a, b| a.submit.total_cmp(&b.submit));
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
    }
    let trace = Trace { users, jobs };
    debug_assert!(trace.validate().is_ok());
    trace
}

/// One timed path.
pub struct PathRun {
    pub label: &'static str,
    pub report: SimReport,
    pub wall: Duration,
}

impl PathRun {
    /// Completed tasks per wall-clock second.
    pub fn tasks_per_sec(&self) -> f64 {
        self.report.tasks_completed as f64
            / self.wall.as_secs_f64().max(1e-12)
    }

    /// Mean wall-clock cost per scheduler-visible event (placements +
    /// completions) — the quantity the class keying holds ~flat in
    /// user count.
    pub fn per_event_cost(&self) -> Duration {
        let events =
            (self.report.tasks_placed + self.report.tasks_completed).max(1);
        self.wall / events as u32
    }
}

/// The classed vs per-user comparison.
pub struct UserScaleResult {
    pub classed: PathRun,
    pub per_user: PathRun,
    pub users: usize,
    pub classes: usize,
    pub tasks_offered: usize,
}

impl UserScaleResult {
    /// The load-bearing invariant: the class-keyed run is
    /// *bit-identical* to the per-user run — every placement, sample,
    /// and job record.
    pub fn parity_ok(&self) -> bool {
        self.classed.report == self.per_user.report
    }

    /// Wall-clock speedup of the classed path.
    pub fn speedup(&self) -> f64 {
        self.per_user.wall.as_secs_f64()
            / self.classed.wall.as_secs_f64().max(1e-12)
    }
}

fn timed(
    label: &'static str,
    cluster: &Cluster,
    trace: &Trace,
    opts: &SimOpts,
    sched: BestFitDrfh,
) -> PathRun {
    let t0 = Instant::now();
    let report =
        run(cluster.clone(), trace, Box::new(sched), opts.clone());
    PathRun { label, report, wall: t0.elapsed() }
}

/// Run the comparison: `users` tenants over [`DEFAULT_CLASSES`]
/// demand classes on `servers` Table I servers for `duration`
/// seconds.
pub fn run_user_scale(
    seed: u64,
    servers: usize,
    users: usize,
    duration: f64,
) -> UserScaleResult {
    let mut rng = Pcg32::new(seed, 0xc1);
    let cluster = Cluster::google_sample(servers, &mut rng);
    let total_tasks = (servers * 40).clamp(1_000, 400_000);
    let classes = DEFAULT_CLASSES.min(users);
    let trace = classed_trace(users, classes, total_tasks, duration, seed);
    let opts = SimOpts {
        horizon: duration,
        sample_dt: (duration / 200.0).max(10.0),
        ..SimOpts::default()
    };
    let classed =
        timed("classed", &cluster, &trace, &opts, BestFitDrfh::default());
    let per_user = timed(
        "per-user",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::per_user(),
    );
    UserScaleResult {
        classed,
        per_user,
        users,
        classes,
        tasks_offered: trace.total_tasks(),
    }
}

pub fn print(res: &UserScaleResult) {
    println!("== user-scale: class-keyed scheduler state check ==");
    println!(
        "{} users over {} demand classes, {} tasks offered; \
         parity classed==per-user: {}",
        res.users,
        res.classes,
        res.tasks_offered,
        if res.parity_ok() { "OK (bit-identical)" } else { "FAILED" },
    );
    for run in [&res.per_user, &res.classed] {
        println!(
            "{:<10} {:>9.1} ms  {:>10.0} tasks/s  {:>10} per event",
            run.label,
            run.wall.as_secs_f64() * 1e3,
            run.tasks_per_sec(),
            crate::util::bench::fmt_dur(run.per_event_cost()),
        );
    }
    println!("classed speedup {:.2}x", res.speedup());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::DemandTable;

    /// The exp-level smoke: classed and per-user paths must be
    /// bit-identical end to end on a workload with real class sharing
    /// (many users per row, zero-weight cohort included).
    #[test]
    fn smoke_parity_holds() {
        let res = run_user_scale(7, 40, 60, 2_000.0);
        assert!(res.parity_ok(), "classed vs per-user reports diverged");
        assert!(res.classed.report.tasks_placed > 0);
        assert_eq!(res.classes, DEFAULT_CLASSES);
    }

    #[test]
    fn classed_trace_interns_to_the_requested_classes() {
        let t = classed_trace(60, 10, 2_000, 2_000.0, 3);
        t.validate().unwrap();
        assert_eq!(t.users.len(), 60);
        let table = DemandTable::build(&t.users);
        assert_eq!(table.classes(), 10);
        // the weight cycle includes a zero-weight cohort
        assert!(t.users.iter().any(|u| u.weight == 0.0));
        // clamped: never more classes than users
        let tiny = classed_trace(3, 10, 100, 500.0, 4);
        assert_eq!(DemandTable::build(&tiny.users).classes(), 3);
        // deterministic
        let a = classed_trace(20, 5, 1_000, 1_000.0, 9);
        let b = classed_trace(20, 5, 1_000, 1_000.0, 9);
        assert_eq!(a.total_tasks(), b.total_tasks());
    }
}
