//! §Perf: flat, borrow-only job/task state for the simulation engine
//! (the users/jobs/tasks model of paper Sec. III-A, laid out for the
//! Sec. VI trace-replay scale).
//!
//! The seed engine kept three parallel copies of per-job state: a
//! `JobSim` struct per job, a `trace_tasks: Vec<Vec<f64>>` clone of
//! every task duration (consumed at arrival), and a per-user
//! `VecDeque<JobQueue>` where each `JobQueue` owned *another*
//! duration container. Every placement chased two heap pointers into
//! a per-job allocation, and a million-task trace paid a million
//! duration copies plus ~#jobs transient allocations.
//!
//! [`TaskArena`] replaces all of that with structure-of-arrays
//! columns indexed by the job id the trace already assigns
//! (`u32`-sized — 4 G jobs is beyond any trace we replay):
//!
//! * durations are **never copied** — the arena borrows each job's
//!   `&[TaskSpec]` slice straight out of the [`Trace`] (stored once,
//!   for the lifetime of the run);
//! * the un-placed frontier of a job is a single `u32` cursor
//!   (`next`), not a shrinking deque;
//! * completion tracking is a `u32` countdown (`open`).
//!
//! The engine's per-user round-robin queue then shrinks to a
//! `VecDeque<u32>` of job ids — one flat ring per user, no per-job
//! containers on the hot path.
//!
//! [`DemandTable`] interns the per-user demand rows: Google-like
//! traces draw user demands from a handful of profile classes, so the
//! engine can precompute per-*class* derived quantities (dominant
//! delta, blocked-index fit keys) once instead of per user — the
//! difference between O(users) and O(classes) setup work when the
//! user count scales toward the ROADMAP's millions.

use crate::cluster::ResVec;
use crate::workload::{TaskSpec, Trace, UserSpec};
use std::collections::HashMap;

/// Structure-of-arrays view of a trace's jobs, borrowing all task
/// durations from the trace itself.
pub struct TaskArena<'t> {
    /// Per-job task slice, borrowed from `trace.jobs[j].tasks`.
    tasks: Vec<&'t [TaskSpec]>,
    /// Owning user per job.
    user: Vec<u32>,
    /// Submission time per job.
    submit: Vec<f64>,
    /// Cursor: tasks `0..next[j]` have been placed.
    next: Vec<u32>,
    /// Tasks not yet *completed* (placed or not).
    open: Vec<u32>,
    /// Interned demand rows for the trace's users.
    demands: DemandTable,
}

impl<'t> TaskArena<'t> {
    pub fn new(trace: &'t Trace) -> Self {
        let nj = trace.jobs.len();
        assert!(nj <= u32::MAX as usize, "trace exceeds u32 job ids");
        let mut tasks = Vec::with_capacity(nj);
        let mut user = Vec::with_capacity(nj);
        let mut submit = Vec::with_capacity(nj);
        let mut open = Vec::with_capacity(nj);
        for j in &trace.jobs {
            assert!(
                j.tasks.len() <= u32::MAX as usize,
                "job exceeds u32 task count"
            );
            tasks.push(j.tasks.as_slice());
            user.push(j.user as u32);
            submit.push(j.submit);
            open.push(j.tasks.len() as u32);
        }
        TaskArena {
            tasks,
            user,
            submit,
            next: vec![0; nj],
            open,
            demands: DemandTable::build(&trace.users),
        }
    }

    /// Number of jobs.
    #[inline]
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    #[inline]
    pub fn job_user(&self, j: usize) -> usize {
        self.user[j] as usize
    }

    #[inline]
    pub fn job_submit(&self, j: usize) -> f64 {
        self.submit[j]
    }

    /// Total tasks of job `j`.
    #[inline]
    pub fn job_len(&self, j: usize) -> usize {
        self.tasks[j].len()
    }

    /// Tasks of `j` not yet placed.
    #[inline]
    pub fn unplaced(&self, j: usize) -> usize {
        self.tasks[j].len() - self.next[j] as usize
    }

    /// Tasks of `j` not yet completed.
    #[inline]
    pub fn open(&self, j: usize) -> usize {
        self.open[j] as usize
    }

    /// Pop the next un-placed task of `j`, returning its duration.
    #[inline]
    pub fn take_next(&mut self, j: usize) -> f64 {
        let cur = self.next[j] as usize;
        debug_assert!(cur < self.tasks[j].len(), "job {j} over-drawn");
        self.next[j] += 1;
        self.tasks[j][cur].duration
    }

    /// Record one task completion; true when the whole job finished.
    #[inline]
    pub fn complete_one(&mut self, j: usize) -> bool {
        debug_assert!(self.open[j] > 0, "job {j} over-completed");
        self.open[j] -= 1;
        self.open[j] == 0
    }

    /// The interned demand rows of the trace's users.
    pub fn demands(&self) -> &DemandTable {
        &self.demands
    }
}

// ---------------------------------------------------------- interning

/// Intern a sequence of demand rows by exact bit pattern: returns the
/// distinct rows (in first-appearance order) and a dense `u32` class
/// id per input row. Keying on the bits means `-0.0` vs `0.0` or
/// ulp-different rows never alias — bit-identical semantics above all.
///
/// The single interning implementation behind both
/// [`DemandTable::build`] (trace side, [`UserSpec`] rows) and
/// `sched::users::DemandClasses` (scheduler side, `UserState` rows):
/// every class-keyed structure relies on the same dense-id contract.
pub fn intern_rows<'a>(
    rows_in: impl IntoIterator<Item = &'a ResVec>,
) -> (Vec<ResVec>, Vec<u32>) {
    let mut rows: Vec<ResVec> = Vec::new();
    let mut class_of = Vec::new();
    // order-independent HashMap use (lint hash-iter rule): keyed
    // `entry` lookups only, never iterated — class ids are assigned by
    // input order (first appearance), not by map order
    let mut seen: HashMap<Vec<u64>, u32> = HashMap::new();
    for d in rows_in {
        let key: Vec<u64> =
            d.as_slice().iter().map(|x| x.to_bits()).collect();
        let class = *seen.entry(key).or_insert_with(|| {
            rows.push(*d);
            (rows.len() - 1) as u32
        });
        class_of.push(class);
    }
    (rows, class_of)
}

/// Distinct per-user demand rows, deduplicated by exact bit pattern,
/// with a user → class map. Derived per-task quantities can then be
/// computed once per class and fanned out.
#[derive(Clone, Debug)]
pub struct DemandTable {
    rows: Vec<ResVec>,
    class_of: Vec<u32>,
}

impl DemandTable {
    pub fn build(users: &[UserSpec]) -> Self {
        let (rows, class_of) =
            intern_rows(users.iter().map(|u| &u.demand));
        DemandTable { rows, class_of }
    }

    /// Number of distinct demand rows.
    pub fn classes(&self) -> usize {
        self.rows.len()
    }

    pub fn users(&self) -> usize {
        self.class_of.len()
    }

    #[inline]
    pub fn class_of(&self, user: usize) -> usize {
        self.class_of[user] as usize
    }

    /// The full user → class map (dense `u32` class ids) — what the
    /// engine hands to the class-keyed scheduler structures
    /// (`sched::index::BlockedIndex::classed`).
    #[inline]
    pub fn class_map(&self) -> &[u32] {
        &self.class_of
    }

    #[inline]
    pub fn row(&self, class: usize) -> &ResVec {
        &self.rows[class]
    }

    /// Compute `f` once per distinct row and fan the results out to a
    /// per-user vector — the interning win for derived quantities.
    pub fn per_user<T: Copy>(&self, f: impl Fn(&ResVec) -> T) -> Vec<T> {
        let per_class: Vec<T> = self.rows.iter().map(&f).collect();
        self.class_of.iter().map(|&c| per_class[c as usize]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::JobSpec;

    fn trace() -> Trace {
        let d = ResVec::cpu_mem(0.2, 0.3);
        Trace {
            users: vec![
                UserSpec { demand: d, weight: 1.0 },
                UserSpec { demand: ResVec::cpu_mem(0.4, 0.1), weight: 2.0 },
                UserSpec { demand: d, weight: 0.5 }, // same row as user 0
            ],
            jobs: vec![
                JobSpec {
                    id: 0,
                    user: 1,
                    submit: 5.0,
                    tasks: vec![
                        TaskSpec { duration: 10.0 },
                        TaskSpec { duration: 20.0 },
                    ],
                },
                JobSpec {
                    id: 1,
                    user: 0,
                    submit: 9.0,
                    tasks: vec![TaskSpec { duration: 7.0 }],
                },
            ],
        }
    }

    #[test]
    fn arena_mirrors_trace_without_copying_durations() {
        let t = trace();
        let mut a = TaskArena::new(&t);
        assert_eq!(a.len(), 2);
        assert_eq!(a.job_user(0), 1);
        assert_eq!(a.job_submit(1), 9.0);
        assert_eq!(a.job_len(0), 2);
        assert_eq!(a.unplaced(0), 2);
        assert_eq!(a.take_next(0), 10.0);
        assert_eq!(a.unplaced(0), 1);
        assert_eq!(a.take_next(0), 20.0);
        assert_eq!(a.unplaced(0), 0);
        // durations still live in the trace — the arena borrowed them
        assert_eq!(t.jobs[0].tasks[0].duration, 10.0);
        assert_eq!(a.open(0), 2);
        assert!(!a.complete_one(0));
        assert!(a.complete_one(0));
        assert_eq!(a.open(0), 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "over-drawn")]
    fn arena_overdraw_panics_in_debug() {
        let t = trace();
        let mut a = TaskArena::new(&t);
        a.take_next(1);
        a.take_next(1);
    }

    #[test]
    fn demand_rows_intern_by_bits() {
        let t = trace();
        let table = DemandTable::build(&t.users);
        assert_eq!(table.users(), 3);
        assert_eq!(table.classes(), 2);
        assert_eq!(table.class_of(0), table.class_of(2));
        assert_ne!(table.class_of(0), table.class_of(1));
        assert_eq!(*table.row(table.class_of(1)), ResVec::cpu_mem(0.4, 0.1));
        // derived quantities computed per class, fanned per user
        let mins = table.per_user(|d| d.min());
        assert_eq!(mins.len(), 3);
        assert!((mins[0] - 0.2).abs() < 1e-12);
        assert!((mins[1] - 0.1).abs() < 1e-12);
        assert_eq!(mins[0], mins[2]);
    }

    #[test]
    fn interning_distinguishes_bit_different_rows() {
        let users = vec![
            UserSpec { demand: ResVec::cpu_mem(0.0, 1.0), weight: 1.0 },
            UserSpec { demand: ResVec::cpu_mem(-0.0, 1.0), weight: 1.0 },
        ];
        let table = DemandTable::build(&users);
        assert_eq!(table.classes(), 2, "-0.0 must not alias 0.0");
    }
}
