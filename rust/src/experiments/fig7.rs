//! Fig. 7 — per-user task completion ratio under Best-Fit DRFH vs
//! Slots (the scatter whose bubbles scale with tasks submitted).
//!
//! Paper reference: Best-Fit yields a higher ratio for almost every
//! user; ~20% of users complete *all* tasks under Best-Fit but not
//! under Slots.

use super::fig5::bestfit_vs_slots_factories;
use super::runner;
use super::{write_csv, EvalSetup};

#[derive(Clone, Debug)]
pub struct Fig7Result {
    /// (user, submitted, ratio under best-fit, ratio under slots)
    pub users: Vec<(usize, usize, f64, f64)>,
}

impl Fig7Result {
    /// Fraction of users whose ratio is >= the slots ratio.
    pub fn frac_not_worse(&self) -> f64 {
        let n = self.users.len().max(1);
        self.users.iter().filter(|(_, _, b, s)| b >= s).count() as f64
            / n as f64
    }

    /// Fraction of users complete under best-fit but not under slots.
    pub fn frac_complete_only_bestfit(&self) -> f64 {
        let n = self.users.len().max(1);
        self.users
            .iter()
            .filter(|(_, _, b, s)| *b >= 1.0 - 1e-12 && *s < 1.0)
            .count() as f64
            / n as f64
    }
}

pub fn run_fig7(setup: &EvalSetup) -> Fig7Result {
    let mut reports = runner::sweep(
        &setup.cluster,
        &setup.trace,
        &setup.opts,
        bestfit_vs_slots_factories(),
    );
    let slots = reports.pop().expect("slots report");
    let bf = reports.pop().expect("best-fit report");
    let users = bf
        .user_tasks
        .iter()
        .zip(&slots.user_tasks)
        .enumerate()
        .filter(|(_, (b, _))| b.submitted > 0)
        .map(|(u, (b, s))| (u, b.submitted, b.ratio(), s.ratio()))
        .collect();
    Fig7Result { users }
}

pub fn print(res: &Fig7Result) {
    println!("== Fig. 7: per-user task completion ratio ==");
    println!("users with submissions: {}", res.users.len());
    println!(
        "best-fit not worse than slots: {:.0}% of users (paper: almost all)",
        res.frac_not_worse() * 100.0
    );
    println!(
        "complete under best-fit only: {:.0}% of users (paper: ~20%)",
        res.frac_complete_only_bestfit() * 100.0
    );
    let mean_bf: f64 = res.users.iter().map(|u| u.2).sum::<f64>()
        / res.users.len().max(1) as f64;
    let mean_sl: f64 = res.users.iter().map(|u| u.3).sum::<f64>()
        / res.users.len().max(1) as f64;
    println!(
        "mean completion ratio: best-fit {:.2}, slots {:.2}",
        mean_bf, mean_sl
    );
    write_csv(
        "fig7_completion_ratio.csv",
        "user,submitted,bestfit_ratio,slots_ratio",
        &res.users
            .iter()
            .map(|(u, n, b, s)| format!("{u},{n},{b:.4},{s:.4}"))
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bestfit_dominates_completion_ratios() {
        let setup = EvalSetup::with_duration(19, 120, 12, 12_000.0);
        let res = run_fig7(&setup);
        assert!(!res.users.is_empty());
        assert!(
            res.frac_not_worse() > 0.6,
            "best-fit should dominate for most users, got {:.2}",
            res.frac_not_worse()
        );
        let mean_bf: f64 = res.users.iter().map(|u| u.2).sum::<f64>()
            / res.users.len() as f64;
        let mean_sl: f64 = res.users.iter().map(|u| u.3).sum::<f64>()
            / res.users.len() as f64;
        assert!(mean_bf > mean_sl, "bf {mean_bf:.3} !> slots {mean_sl:.3}");
    }
}
