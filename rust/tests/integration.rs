//! End-to-end integration tests: trace generation -> simulation ->
//! metrics, across schedulers; plus config-driven runs and the paper's
//! worked example through the whole stack.

use drfh::allocator::{self, FluidUser};
use drfh::cluster::{Cluster, ResVec};
use drfh::config::ExperimentConfig;
use drfh::coordinator::{Coordinator, Engine};
use drfh::experiments::EvalSetup;
use drfh::sched::{BestFitDrfh, FirstFitDrfh, SlotsScheduler};
use drfh::sim::{run, SimOpts};
use drfh::util::Pcg32;
use drfh::workload::{GoogleLikeConfig, TraceGenerator};

/// The paper's Fig. 1-3 story end-to-end: the discrete Best-Fit
/// scheduler on the Fig. 1 cluster converges to the fluid DRFH
/// allocation (10 tasks per user; naive per-server DRF only reaches 6).
#[test]
fn paper_example_discrete_matches_fluid() {
    let cluster = Cluster::fig1_example();
    let trace = drfh::workload::Trace {
        users: vec![
            drfh::workload::UserSpec {
                demand: ResVec::cpu_mem(0.2, 1.0),
                weight: 1.0,
            },
            drfh::workload::UserSpec {
                demand: ResVec::cpu_mem(1.0, 0.2),
                weight: 1.0,
            },
        ],
        jobs: (0..2)
            .map(|u| drfh::workload::JobSpec {
                id: u,
                user: u,
                submit: 0.0,
                tasks: vec![
                    drfh::workload::TaskSpec { duration: 1000.0 };
                    12
                ],
            })
            .collect(),
    };
    let r = run(
        cluster.clone(),
        &trace,
        Box::new(BestFitDrfh::default()),
        SimOpts { horizon: 100.0, sample_dt: 10.0, track_user_series: false, ..SimOpts::default() },
    );
    // fluid optimum: 10 tasks each (Fig. 3)
    assert_eq!(r.tasks_placed, 20, "discrete best-fit should reach 10+10");
    let fluid = allocator::solve(
        &cluster,
        &[
            FluidUser::unweighted(ResVec::cpu_mem(0.2, 1.0)),
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.2)),
        ],
    );
    assert!((fluid.tasks[0] - 10.0).abs() < 1e-5);
    assert!((fluid.tasks[1] - 10.0).abs() < 1e-5);
}

/// All three schedulers drive the same trace to a consistent
/// accounting (placed >= completed, ratios in [0,1], no panics), and
/// the DRFH policies never overcommit any server.
#[test]
fn all_schedulers_run_same_trace() {
    let mut rng = Pcg32::seeded(77);
    let cluster = Cluster::google_sample(80, &mut rng);
    let gen = TraceGenerator::new(GoogleLikeConfig {
        users: 10,
        duration: 6_000.0,
        jobs_per_user: 8.0,
        max_tasks_per_job: 200,
        ..Default::default()
    });
    let trace = gen.generate(5);
    let opts =
        SimOpts { horizon: 6_000.0, sample_dt: 60.0, track_user_series: false, ..SimOpts::default() };

    let slots = SlotsScheduler::new(&cluster, 14);
    for report in [
        run(cluster.clone(), &trace, Box::new(BestFitDrfh::default()), opts.clone()),
        run(cluster.clone(), &trace, Box::new(FirstFitDrfh::default()), opts.clone()),
        run(cluster.clone(), &trace, Box::new(slots), opts.clone()),
    ] {
        assert!(report.tasks_completed <= report.tasks_placed);
        assert!(report.tasks_placed <= trace.total_tasks());
        for u in &report.user_tasks {
            assert!(u.completed <= u.submitted);
        }
        for &v in report.cpu_util.v.iter().chain(&report.mem_util.v) {
            assert!((0.0..=1.0 + 1e-9).contains(&v), "{}: util {v}", report.scheduler);
        }
        assert!(report.tasks_placed > 0, "{} placed nothing", report.scheduler);
    }
}

/// Determinism: identical seeds produce identical reports.
#[test]
fn simulation_is_deterministic() {
    let setup_a = EvalSetup::with_duration(31, 60, 8, 5_000.0);
    let setup_b = EvalSetup::with_duration(31, 60, 8, 5_000.0);
    let ra = run(
        setup_a.cluster.clone(),
        &setup_a.trace,
        Box::new(BestFitDrfh::default()),
        setup_a.opts.clone(),
    );
    let rb = run(
        setup_b.cluster.clone(),
        &setup_b.trace,
        Box::new(BestFitDrfh::default()),
        setup_b.opts.clone(),
    );
    assert_eq!(ra.tasks_placed, rb.tasks_placed);
    assert_eq!(ra.tasks_completed, rb.tasks_completed);
    assert_eq!(ra.jobs.len(), rb.jobs.len());
    assert_eq!(ra.cpu_util.v, rb.cpu_util.v);
}

/// Config-driven entry point: parse TOML, build everything, run.
#[test]
fn config_driven_simulation() {
    let cfg = ExperimentConfig::from_toml(
        r#"
        seed = 3
        [cluster]
        servers = 50
        [workload]
        users = 6
        duration = 3000.0
        jobs_per_user = 4.0
        max_tasks_per_job = 50
        [sim]
        horizon = 3000.0
        sample_dt = 50.0
        [scheduler]
        policy = "firstfit"
        "#,
    )
    .unwrap();
    let cluster = cfg.build_cluster();
    let trace = cfg.build_trace();
    let sched = cfg.build_scheduler(&cluster).unwrap();
    assert_eq!(sched.name(), "firstfit-drfh");
    let report =
        run(cluster, &trace, sched, cfg.sim_opts().expect("valid sim opts"));
    assert!(report.tasks_placed > 0);
}

/// Trace JSON capsule round-trips through the simulator unchanged.
#[test]
fn trace_json_capsule_reproduces_run() {
    let gen = TraceGenerator::new(GoogleLikeConfig {
        users: 5,
        duration: 2_000.0,
        jobs_per_user: 4.0,
        max_tasks_per_job: 30,
        ..Default::default()
    });
    let trace = gen.generate(11);
    let trace2 =
        drfh::workload::Trace::from_json(&trace.to_json()).unwrap();
    let mut rng = Pcg32::seeded(11);
    let cluster = Cluster::google_sample(40, &mut rng);
    let opts =
        SimOpts { horizon: 2_000.0, sample_dt: 50.0, track_user_series: false, ..SimOpts::default() };
    let ra = run(cluster.clone(), &trace, Box::new(BestFitDrfh::default()), opts.clone());
    let rb = run(cluster, &trace2, Box::new(BestFitDrfh::default()), opts);
    assert_eq!(ra.tasks_placed, rb.tasks_placed);
    assert_eq!(ra.cpu_util.v, rb.cpu_util.v);
}

/// The native coordinator agrees with the DES on a static workload:
/// same number of placements when nothing completes.
#[test]
fn coordinator_matches_simulation_fill() {
    let mut rng = Pcg32::seeded(55);
    let cluster = Cluster::google_sample(60, &mut rng);
    let demands: Vec<ResVec> = (0..6)
        .map(|_| ResVec::cpu_mem(rng.uniform(0.05, 0.3), rng.uniform(0.05, 0.3)))
        .collect();
    // coordinator fill: batch-submit so all users are queued before
    // any placement (mirrors the engine's same-time event batching)
    let coord = Coordinator::spawn(
        &cluster,
        &demands,
        &[1.0; 6],
        Engine::Native,
    );
    coord
        .submit_many(
            (0..6)
                .map(|u| drfh::coordinator::Submission { user: u, count: 500 })
                .collect(),
        )
        .unwrap();
    let stats = coord.stats().unwrap();
    coord.shutdown().unwrap();

    // DES fill with an effectively infinite horizon freeze (tasks never
    // finish within the horizon)
    let trace = drfh::workload::Trace {
        users: demands
            .iter()
            .map(|d| drfh::workload::UserSpec { demand: *d, weight: 1.0 })
            .collect(),
        jobs: (0..6)
            .map(|u| drfh::workload::JobSpec {
                id: u,
                user: u,
                submit: 0.0,
                tasks: vec![
                    drfh::workload::TaskSpec { duration: 1e9 };
                    500
                ],
            })
            .collect(),
    };
    let r = run(
        cluster,
        &trace,
        Box::new(BestFitDrfh::default()),
        SimOpts { horizon: 10.0, sample_dt: 5.0, track_user_series: false, ..SimOpts::default() },
    );
    // both fill the cluster greedily under progressive filling; the
    // f32 (coordinator) vs f64 (engine) fit checks can differ by a task
    // or two at the margin
    let diff = (stats.placed as i64 - r.tasks_placed as i64).abs();
    assert!(
        diff <= 6,
        "coordinator {} vs sim {} placements",
        stats.placed,
        r.tasks_placed
    );
}

/// Overcommitted Slots delays individual completions (processor
/// sharing is work-conserving on the makespan, but every single task
/// finishes late): four 1-task jobs on a 1-server pool finish at
/// 100/100/200/200 under Best-Fit vs 800 each under 8 slots (load 2,
/// cubic thrashing -> rate 1/8).
#[test]
fn slots_overcommit_inflates_completion_times() {
    let cluster = Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
    let trace = drfh::workload::Trace {
        users: vec![drfh::workload::UserSpec {
            demand: ResVec::cpu_mem(0.5, 0.5),
            weight: 1.0,
        }],
        jobs: (0..4)
            .map(|j| drfh::workload::JobSpec {
                id: j,
                user: 0,
                submit: 0.0,
                tasks: vec![drfh::workload::TaskSpec { duration: 100.0 }],
            })
            .collect(),
    };
    let opts =
        SimOpts { horizon: 4_000.0, sample_dt: 10.0, track_user_series: false, ..SimOpts::default() };
    let bf = run(cluster.clone(), &trace, Box::new(BestFitDrfh::default()), opts.clone());
    let slots = run(
        cluster.clone(),
        &trace,
        Box::new(SlotsScheduler::new(&cluster, 8)),
        opts,
    );
    let mean = |jobs: &[drfh::metrics::JobRecord]| {
        jobs.iter().map(|j| j.completion_time()).sum::<f64>()
            / jobs.len() as f64
    };
    assert_eq!(bf.jobs.len(), 4);
    assert_eq!(slots.jobs.len(), 4);
    // best-fit: 2 at a time at rate 1 -> completions 100,100,200,200
    assert!((mean(&bf.jobs) - 150.0).abs() < 1e-6, "bf mean {}", mean(&bf.jobs));
    // slots: all 4 at once at load 2 -> thrashing rate 1/8 -> every
    // task finishes at 800
    assert!(
        (mean(&slots.jobs) - 800.0).abs() < 1e-6,
        "slots mean {}",
        mean(&slots.jobs)
    );
}

/// Weighted users (paper Sec. V-A): in the discrete scheduler a
/// weight-2 user should converge to twice the dominant share of a
/// weight-1 user with identical demands.
#[test]
fn weighted_users_share_proportionally_in_sim() {
    let cluster = Cluster::from_capacities(&[
        ResVec::cpu_mem(8.0, 8.0),
        ResVec::cpu_mem(8.0, 8.0),
    ]);
    let demand = ResVec::cpu_mem(0.5, 0.5);
    let trace = drfh::workload::Trace {
        users: vec![
            drfh::workload::UserSpec { demand, weight: 2.0 },
            drfh::workload::UserSpec { demand, weight: 1.0 },
        ],
        jobs: (0..2)
            .map(|u| drfh::workload::JobSpec {
                id: u,
                user: u,
                submit: 0.0,
                tasks: vec![drfh::workload::TaskSpec { duration: 1e6 }; 64],
            })
            .collect(),
    };
    let r = run(
        cluster,
        &trace,
        Box::new(BestFitDrfh::default()),
        SimOpts { horizon: 10.0, sample_dt: 5.0, track_user_series: true, ..SimOpts::default() },
    );
    // 32 concurrent tasks fit; weighted filling gives ~21 vs ~11
    assert_eq!(r.tasks_placed, 32);
    let s0 = *r.user_dom_share[0].v.last().unwrap();
    let s1 = *r.user_dom_share[1].v.last().unwrap();
    assert!(
        (s0 / s1 - 2.0).abs() < 0.15,
        "weighted shares {s0:.4} vs {s1:.4} not ~2:1"
    );
}

/// Finite demands release capacity (paper Sec. V-A, discrete analogue
/// of the fluid cap test): once a small user drains, the big user
/// absorbs the freed share.
#[test]
fn finite_backlog_releases_capacity_in_sim() {
    let cluster =
        Cluster::from_capacities(&[ResVec::cpu_mem(4.0, 4.0)]);
    let demand = ResVec::cpu_mem(1.0, 1.0);
    let trace = drfh::workload::Trace {
        users: vec![
            drfh::workload::UserSpec { demand, weight: 1.0 },
            drfh::workload::UserSpec { demand, weight: 1.0 },
        ],
        jobs: vec![
            drfh::workload::JobSpec {
                id: 0,
                user: 0,
                submit: 0.0,
                tasks: vec![drfh::workload::TaskSpec { duration: 10.0 }; 2],
            },
            drfh::workload::JobSpec {
                id: 1,
                user: 1,
                submit: 0.0,
                tasks: vec![drfh::workload::TaskSpec { duration: 10.0 }; 8],
            },
        ],
    };
    let r = run(
        cluster,
        &trace,
        Box::new(BestFitDrfh::default()),
        SimOpts { horizon: 100.0, sample_dt: 1.0, track_user_series: false, ..SimOpts::default() },
    );
    // phase 1: 2+2 split; user 0 done at t=10; user 1 then runs 4-wide:
    // remaining 6 tasks in two waves -> job 1 finishes at 30
    assert_eq!(r.tasks_completed, 10);
    let j1 = r.jobs.iter().find(|j| j.job == 1).unwrap();
    assert!((j1.finish - 30.0).abs() < 1e-6, "job 1 at {}", j1.finish);
}
