//! Discrete-event cluster simulator.
//!
//! Drives a [`Trace`] through a [`Scheduler`] over a [`Cluster`] and
//! records everything the paper's evaluation section plots: utilization
//! time series (Fig. 5), per-user share trajectories (Fig. 4), job
//! completion times (Fig. 6), and per-user task completion ratios
//! (Fig. 7/8).
//!
//! ## Processor sharing
//!
//! DRFH schedulers never exceed server capacity, so their tasks run at
//! rate 1 and a task placed at `t` finishes at `t + duration`. The Slots
//! baseline, however, ignores real demands and can overcommit a server;
//! we model the resulting contention as egalitarian processor sharing
//! with thrashing: every task on server `l` progresses at rate
//! `f_l = min(1, 1/load_l³)` where `load_l = max_r usage_lr / c_lr`
//! (the cubic term models paging/scheduling overhead; see
//! `cluster::Server::rate`). Each server keeps a virtual
//! clock advancing at `f_l`; a task with service demand `w` placed at
//! virtual time `V` completes when the clock reaches `V + w`. Rate
//! changes (placements/completions) reschedule the server's next
//! completion event; stale events are skipped via a per-server
//! generation counter.
//!
//! ## §Perf: the trace-scale data plane
//!
//! Three independently gated pieces keep a ~10⁶-task, k = 2000 run
//! inside one machine's memory and cache budget (`benches/sim_scale.rs`
//! measures all three; `tests/engine_parity.rs` pins the semantics):
//!
//! * **Event queue** ([`SimOpts::queue`]): the engine drives a
//!   [`wheel::SimQueue`] — a calendar-style timer wheel
//!   ([`wheel::TimerWheel`], the default; [`QueueKind::Auto`] tunes
//!   its geometry to the trace's observed duration distribution) or
//!   the seed's `BinaryHeap` ([`wheel::HeapQueue`], the naive parity
//!   reference). All drain in the identical total `(time, seq)`
//!   order, so every scheduling decision and every derived float is
//!   bit-identical across queue choices; the wheel replaces O(log N)
//!   cache-hostile heap walks with O(1) bucket pushes and batched
//!   bucket sorts.
//!
//! * **Task arena** ([`TaskArena`]): per-job state lives in flat
//!   structure-of-arrays columns (u32 cursors/countdowns), task
//!   durations are borrowed once from the [`Trace`] instead of being
//!   cloned per job, per-user queues are flat `VecDeque<u32>` job-id
//!   rings, and per-user demand rows are interned
//!   ([`crate::workload::DemandTable`]) so derived per-task constants
//!   (dominant delta, blocked-index fit keys) are computed once per
//!   distinct row.
//!
//! * **Metrics gating** ([`SimOpts::metrics`]):
//!   [`MetricsMode::Streaming`] folds job completions into O(1)
//!   streaming accumulators ([`crate::metrics::JobStats`]) and keeps
//!   every time series under a fixed point budget by stride-doubling
//!   decimation, so peak RSS stays ~flat in task count.
//!   [`MetricsMode::Full`] (default) is the seed behavior the figure
//!   harnesses need. `job_stats` is maintained in both modes.
//!
//! ## §Perf: batched drain
//!
//! Scheduling opportunities are handed to the policy one *event wave*
//! at a time: `schedule_loop` builds an [`EngineCtx`] over the
//! engine's state and calls [`Scheduler::drain`] once, and the policy
//! commits every placeable task through [`DrainCtx::place`] /
//! [`DrainCtx::block`] before returning. The engine still owns all
//! state mutation (the ctx methods are the old `place`/block bodies);
//! what moved is the control loop, so indexed policies can refresh
//! their structures once per wave instead of once per decision. The
//! engine stays silent on `on_place` during a drain — the deciding
//! policy already knows — while completions between waves keep firing
//! `on_complete`/`on_free`/`on_ready` as before.
//!
//! ## §Perf: indexed hot path
//!
//! The engine feeds the policies' incremental indexes
//! (`sched::index`, `sched::users`) through three notifications —
//! `on_place` after a commit, `on_complete`/`on_free` after a
//! release, and `on_ready` when a user (re-)enters the schedulable
//! set — and keeps its own blocked set in a class-keyed
//! `sched::index::BlockedIndex` built over the trace's interned
//! demand rows ([`crate::workload::DemandTable`]): a completion on
//! server `l` re-checks only the blocked demand *classes* whose
//! minimum demand component fits under `l`'s smallest per-resource
//! headroom (a necessary condition for fitting), with one exact
//! `Scheduler::can_fit` probe per candidate class deciding every
//! blocked member of that class (the `can_fit` contract: verdicts
//! depend on the user only through its demand). The candidate set is
//! a provable superset of the users the seed's linear scan would
//! have unblocked, so the unblocked *set* — and therefore every
//! subsequent decision — is identical (asserted end-to-end by
//! `tests/engine_parity.rs`).
//!
//! ## §Perf: sharded data plane
//!
//! With every single-threaded hot path indexed, the remaining lever
//! is using more than one core *within one simulation*. The paper's
//! placement step is per-server (Best-Fit feasibility and H-score of
//! server `l` depend only on `l`'s own capacity and usage), so the
//! server pool is partitioned into `S` contiguous shards
//! ([`SimOpts::shards`] / [`crate::cluster::ShardSpec`]): each shard
//! owns its servers' [`Server`](crate::cluster::Server) and PS
//! (`ServerSim`) columns plus its own event lane
//! ([`wheel::ShardedQueue`] — a merge cursor restores the exact
//! global `(time, seq)` drain order for any lane routing).
//!
//! Each same-timestamp event wave is drained in two phases:
//!
//! * **propose** (shard-parallel, scoped worker threads for heavy
//!   waves): every live `ServerCheck` advances its shard's PS clock
//!   and pops + releases the completed run entries. Mutations stay
//!   inside the owning shard's columns; the only shared reads are the
//!   static per-user demand vectors.
//! * **commit** (sequential, main thread): the wave is replayed in
//!   `(time, seq)` order, applying arrivals and each proposed
//!   completion's cross-cutting effects — scheduler notifications,
//!   user shares, report counters, job bookkeeping, seq-consuming
//!   server refreshes — through the same code the sequential engine
//!   runs, in the same order.
//!
//! Samples split a wave into segments (a sample reads whole-cluster
//! utilization mid-wave), and the scheduler still runs once per
//! timestamp after the wave commits. Because the propose phase
//! computes exactly what the sequential drain would have computed
//! (completion sets are a pure function of per-shard state) and the
//! commit replays it in the sequential order, every `SimReport` float
//! is bit-identical for every shard count — `S = 1` *is* the
//! sequential engine, not a fork, and `tests/engine_parity.rs` pins
//! the equivalence across `S × queue` choices.
//!
//! ## Faults: crash, retry, recover
//!
//! A [`FaultPlan`] ([`SimOpts::faults`], module
//! [`crate::sim::faults`]) compiles into `ServerDown`/`ServerUp`
//! events at construction time, pushed *after* every arrival and the
//! first sample so an empty plan leaves seq assignment — and
//! therefore every decision and every float — untouched
//! (`FaultPlan::none()` parity, pinned in `tests/engine_parity.rs`).
//!
//! On `ServerDown` the engine advances the server's PS clock, evicts
//! every [`RunEntry`] (releasing usage, crediting the consumed work
//! to `wasted_s`), zeroes the server's capacity (saving the original
//! for recovery — a zero-capacity server is infeasible to every
//! fit/score path for free), bumps the PS generation so queued
//! `ServerCheck`s go stale, and tells the policy through the
//! default-no-op [`Scheduler::on_server_down`] hook to drop the
//! server from its placement structures. Each evicted task re-enters
//! its user's queue with its *remaining* work after a deterministic
//! exponential backoff ([`RetryPolicy::backoff`] — a pure function of
//! `(plan seed, task id, attempt)`), until the attempt budget is
//! spent (`tasks_lost`). On `ServerUp` the capacity is restored, the
//! policy notified ([`Scheduler::on_server_up`]), and blocked users
//! re-probed exactly like after a completion.
//!
//! Degradation is measured, not fatal: users whose demand no longer
//! fits anywhere park in the blocked index (no spinning), and the
//! report gains goodput-vs-wasted seconds plus one [`OutageRecord`]
//! per crash — the first sample tick where the spread of weighted
//! dominant shares across active users re-enters the pre-crash
//! baseline + ε closes the record (fairness-recovery time).
//!
//! Sharding: `ServerDown`/`ServerUp` are segment *barriers* like
//! samples (they must order strictly against same-wave
//! `ServerCheck`s, which a propose phase would otherwise batch);
//! `Retry` events replay in the sequential commit like arrivals.
//! Faults are rare relative to checks, so the barrier cost is noise,
//! and every report float stays bit-identical across shard counts.
//!
//! ## Churn: join, leave, flash crowds
//!
//! A [`ChurnPlan`] ([`SimOpts::churn`], module [`crate::sim::churn`])
//! compiles into `UserJoin`/`UserLeave` events at construction time,
//! pushed after the fault transitions so an empty plan leaves seq
//! assignment — and therefore every decision and every float —
//! untouched (`ChurnPlan::none()` parity, pinned in
//! `tests/engine_parity.rs`). User arrays stay fixed-size for the
//! whole run; churn toggles a per-user *presence* flag, so no index
//! ever resizes mid-trace.
//!
//! On `UserLeave` the engine evicts the user's run entries from every
//! server (each heap drained in `(vfinish, seq)` order — the consumed
//! work is credited to `abandoned_s`, the tasks to
//! `tasks_abandoned`), releases the capacity, discards the user's
//! queued and retry-ready work, bumps the user's retry *epoch* so
//! in-flight backoff payloads are abandoned on arrival, drops the
//! user from the blocked set, and tells the policy through the
//! default-no-op [`Scheduler::on_user_leave`] hook to drop it from
//! any user-keyed index. Freed capacity re-probes blocked users
//! exactly like a completion. On `UserJoin` the user is re-admitted
//! with a clean slate ([`Scheduler::on_user_join`]); arrivals for an
//! absent user are dropped and counted. Both transitions are
//! idempotent.
//!
//! Sharding: `UserJoin`/`UserLeave` are segment barriers like the
//! fault transitions (a leave mutates run-entry heaps across *all*
//! shards, so same-wave `ServerCheck`s must order strictly against
//! it). Churn events are rare relative to checks, so the barrier
//! cost is noise, and every report float stays bit-identical across
//! shard counts.

use crate::cluster::{Cluster, ResVec, Server, ShardCount, ShardSpec};
use crate::metrics::shares::ShareSketch;
use crate::metrics::{
    JobRecord, JobStats, MetricsMode, TimeSeries, UserTaskCounts,
};
use crate::sched::index::BlockedIndex;
use crate::sched::{DrainCtx, Scheduler, UserState};
use crate::sim::churn::ChurnPlan;
use crate::sim::faults::{FaultPlan, OutageRecord, RetryPolicy};
use crate::sim::wheel::{
    self, EventQueue, QueueKind, ShardedQueue, SimQueue, TimerWheel,
};
use crate::workload::{TaskArena, Trace};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

/// Simulation options.
#[derive(Clone, Debug)]
pub struct SimOpts {
    /// Stop the clock here (seconds). Tasks still running are counted
    /// as incomplete (paper Fig. 7/8 use completion *ratios*).
    pub horizon: f64,
    /// Metrics sampling period (seconds).
    pub sample_dt: f64,
    /// Record per-user share time series (Fig. 4 needs it; the
    /// 2,000-server runs don't and save the memory).
    pub track_user_series: bool,
    /// Event-queue implementation (§Perf): the timer wheel by
    /// default; [`QueueKind::Auto`] re-tunes the wheel geometry from
    /// the trace's observed task-duration distribution
    /// ([`wheel::auto_geometry`] — perf-only, the drain order is
    /// geometry-independent); [`QueueKind::Heap`] is the seed's
    /// binary heap, kept as the naive parity reference. Decision
    /// streams are bit-identical in every case
    /// (`tests/engine_parity.rs`).
    pub queue: QueueKind,
    /// Metrics retention (§Perf): [`MetricsMode::Full`] keeps every
    /// sample and job record; [`MetricsMode::Streaming`] bounds
    /// memory for trace-scale runs.
    pub metrics: MetricsMode,
    /// Per-user dominant-share *sketches* (§Perf): `Some(budget)`
    /// maintains one [`ShareSketch`] per user — Welford moments, P²
    /// median/p90 and a trajectory decimated to at most `budget`
    /// points (0 = exact retention) — fed at every sample tick. The
    /// bounded-memory alternative to [`SimOpts::track_user_series`]
    /// for Fig. 4-style trajectories at large user counts.
    pub share_sketch: Option<usize>,
    /// Server-pool shards for the parallel data plane (§Perf: sharded
    /// data plane). The pool is split into contiguous shards, each
    /// owning its servers' PS state and event lane; heavy event waves
    /// propose shard-locally on scoped worker threads before a
    /// sequential commit replays the wave in the global `(time, seq)`
    /// order — so the report is bit-identical for every shard count
    /// (`tests/engine_parity.rs`). `Fixed(1)` (the default) *is* the
    /// sequential engine, not a fork of it; `Auto` uses one shard per
    /// core. `DRFH_SEQ=1` disables the worker threads without
    /// changing results.
    pub shards: ShardCount,
    /// Wave-boundary invariant auditing ([`crate::sim::audit`]): after
    /// every event wave, prove capacity conservation, index-vs-naive
    /// decision cross-checks, drain-order monotonicity, shard-lane
    /// routing and arena/user accounting against the authoritative
    /// state, panicking with a structured dump on the first violation.
    /// Decision-neutral by construction — an audit-enabled run
    /// produces a bit-identical [`SimReport`] to an audit-off run
    /// (`tests/engine_parity.rs`). Also switchable per-process via
    /// `DRFH_AUDIT=1` and per-config via `[sim] audit`.
    pub audit: bool,
    /// Deterministic server failure/recovery schedule (module docs,
    /// §Faults). [`FaultPlan::none`] (the default) injects nothing
    /// and leaves the engine bit-identical to a fault-free build.
    pub faults: FaultPlan,
    /// Retry discipline for tasks evicted by a crash (attempt budget
    /// + deterministic exponential backoff).
    pub retry: RetryPolicy,
    /// Deterministic user join/leave schedule (module docs, §Churn).
    /// [`ChurnPlan::none`] (the default) injects nothing and leaves
    /// the engine bit-identical to a churn-free build.
    pub churn: ChurnPlan,
}

impl Default for SimOpts {
    fn default() -> Self {
        SimOpts {
            horizon: 86_400.0,
            sample_dt: 30.0,
            track_user_series: false,
            queue: QueueKind::Wheel,
            metrics: MetricsMode::Full,
            share_sketch: None,
            shards: ShardCount::Fixed(1),
            audit: false,
            faults: FaultPlan::none(),
            retry: RetryPolicy::default(),
            churn: ChurnPlan::none(),
        }
    }
}

/// Everything measured during a run.
#[derive(Clone, Debug, PartialEq)]
pub struct SimReport {
    pub scheduler: String,
    pub cpu_util: TimeSeries,
    pub mem_util: TimeSeries,
    /// Per-user global dominant share over time (when tracked).
    pub user_dom_share: Vec<TimeSeries>,
    /// Per-user dominant-share sketches (when
    /// [`SimOpts::share_sketch`] is set; empty otherwise).
    pub share_sketches: Vec<ShareSketch>,
    /// Per-user CPU / memory share of the pool over time (when tracked).
    pub user_cpu_share: Vec<TimeSeries>,
    pub user_mem_share: Vec<TimeSeries>,
    /// Jobs that completed before the horizon (empty under
    /// [`MetricsMode::Streaming`] — use [`SimReport::job_stats`]).
    pub jobs: Vec<JobRecord>,
    /// Streaming job-completion statistics (maintained in every
    /// metrics mode).
    pub job_stats: JobStats,
    pub user_tasks: Vec<UserTaskCounts>,
    pub tasks_placed: usize,
    pub tasks_completed: usize,
    /// Time-averaged utilizations over the horizon.
    pub avg_cpu_util: f64,
    pub avg_mem_util: f64,
    /// Useful service seconds delivered: the full duration of every
    /// *completed* task attempt (a retried task's lost progress is
    /// never double-counted — its completing attempt carries only the
    /// remaining work).
    pub goodput_s: f64,
    /// Service seconds destroyed by crashes: work a task had consumed
    /// when its server went down.
    pub wasted_s: f64,
    /// Run entries evicted by `ServerDown` events.
    pub evictions: usize,
    /// Evicted tasks that re-entered a queue after backoff.
    pub retries: usize,
    /// Evicted tasks abandoned with a spent attempt budget (their
    /// jobs never complete — measured degradation, not an error).
    pub tasks_lost: usize,
    /// One record per crash: pre-crash envy baseline and the sample
    /// tick where fairness recovered (module docs, §Faults).
    pub outages: Vec<OutageRecord>,
    /// Applied `UserJoin` transitions (module docs, §Churn).
    pub user_joins: usize,
    /// Applied `UserLeave` transitions.
    pub user_leaves: usize,
    /// Tasks discarded by churn: a leaver's evicted running tasks,
    /// its queued and retry-parked work, stranded backoff payloads,
    /// and arrivals dropped while absent (measured degradation, not
    /// an error).
    pub tasks_abandoned: usize,
    /// Service seconds a leaver's evicted tasks had consumed when the
    /// departure destroyed them (the churn analogue of `wasted_s`).
    pub abandoned_s: f64,
}

// ---------------------------------------------------------------- events

#[derive(Clone, Copy, Debug, PartialEq)]
pub(super) enum EventKind {
    Arrival(usize),
    ServerCheck { server: usize, gen: u64 },
    Sample,
    /// Fault plan: `server` crashes (evict + zero capacity).
    ServerDown { server: usize },
    /// Fault plan: `server` recovers (restore capacity).
    ServerUp { server: usize },
    /// Backoff expired for the retry payload parked in slab slot
    /// `slot` (`Simulation::retry_pending`) — the slot index keeps
    /// this variant pointer-sized instead of inlining the payload.
    Retry { slot: u32 },
    /// Churn plan: `user` joins (enters service).
    UserJoin { user: usize },
    /// Churn plan: `user` leaves (evict + discard its work).
    UserLeave { user: usize },
}

type Event = wheel::Event<EventKind>;
pub(super) type Events = ShardedQueue<EventKind>;

/// `(index within the current segment, server, generation)` of one
/// gathered `ServerCheck` — the unit of shard-local propose work.
type ShardCheck = (u32, u32, u64);

/// Minimum `ServerCheck` count in a wave segment before the propose
/// phase fans out to scoped worker threads — below this, spawn
/// overhead dwarfs the shard-local work and the inline path (the same
/// function, identical results) wins.
const PAR_MIN_CHECKS: usize = 32;

// ------------------------------------------------------------- run state

#[derive(Clone, Copy, Debug)]
pub(super) struct RunEntry {
    pub(super) vfinish: f64,
    pub(super) seq: u64,
    pub(super) user: u32,
    pub(super) job: u32,
    /// Service demand of *this attempt* (virtual seconds): the trace
    /// duration on attempt 1, the remaining work on a retry. Goodput
    /// and wasted-work accounting both derive from it.
    pub(super) dur: f64,
    /// 1-based attempt number (audited against the retry budget).
    pub(super) attempt: u32,
    /// Stable task identity across retries: the seq of the task's
    /// *first* placement. Deterministic at every shard count (seq
    /// assignment is), and the backoff-jitter key.
    pub(super) task: u64,
}

/// An evicted task waiting out its backoff (slab payload of
/// [`EventKind::Retry`]) or already released into its user's retry
/// queue (`Simulation::retry_ready`).
#[derive(Clone, Copy, Debug)]
pub(super) struct RetryTask {
    pub(super) job: u32,
    pub(super) attempt: u32,
    pub(super) task: u64,
    /// Work left when the crash hit (virtual seconds).
    pub(super) remaining: f64,
    /// The owning user's churn epoch when the eviction happened
    /// (`Simulation::user_epoch`): every `UserLeave` bumps the epoch,
    /// so a payload stranded by a departure is recognized — and
    /// abandoned — when its backoff expires, even if the user has
    /// since rejoined. Always 0 under an empty churn plan.
    pub(super) epoch: u32,
}

impl PartialEq for RunEntry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for RunEntry {}
impl PartialOrd for RunEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RunEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on (vfinish, seq)
        other
            .vfinish
            .total_cmp(&self.vfinish)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

pub(super) struct ServerSim {
    pub(super) vtime: f64,
    pub(super) t_last: f64,
    pub(super) rate: f64,
    pub(super) gen: u64,
    pub(super) running: BinaryHeap<RunEntry>,
}

impl ServerSim {
    fn new() -> Self {
        ServerSim {
            vtime: 0.0,
            t_last: 0.0,
            rate: 1.0,
            gen: 0,
            running: BinaryHeap::new(),
        }
    }

    #[inline]
    fn advance(&mut self, now: f64) {
        if now > self.t_last {
            self.vtime += self.rate * (now - self.t_last);
            self.t_last = now;
        }
    }
}

/// The simulator. `'a` covers both the policy and the replayed trace —
/// the [`TaskArena`] borrows every task duration straight from the
/// trace instead of cloning it.
pub struct Simulation<'a> {
    pub cluster: Cluster,
    pub users: Vec<UserState>,
    pub(super) scheduler: Box<dyn Scheduler + 'a>,
    pub(super) opts: SimOpts,

    /// Per-user round-robin ring of job ids with un-placed tasks.
    /// Tasks are drawn round-robin across the user's jobs (Hadoop
    /// Fair Scheduler semantics: fair across jobs within a pool), so
    /// a small job is never buried behind an earlier big one. The
    /// job's un-placed frontier itself is a u32 cursor in the arena —
    /// no per-job containers on this path.
    pub(super) queues: Vec<VecDeque<u32>>,
    /// Flat SoA job/task state, durations borrowed from the trace.
    pub(super) arena: TaskArena<'a>,
    pub(super) servers: Vec<ServerSim>,
    pub(super) events: Events,
    pub(super) seq: u64,
    pub(super) now: f64,

    pub(super) eligible: Vec<bool>,
    pub(super) blocked: BlockedIndex,
    /// Scratch buffers for unblock candidates (users / demand
    /// classes), avoiding per-completion allocation.
    scratch_unblock: Vec<usize>,
    scratch_classes: Vec<usize>,

    /// §Perf: sharded data plane (module docs). `spec` partitions the
    /// server pool; shard count 1 routes through the sequential
    /// [`Simulation::run`] loop unchanged.
    pub(super) spec: ShardSpec,
    /// Whether the propose phase may use worker threads at all
    /// (multiple shards, no `DRFH_SEQ`, more than one core). The
    /// inline fallback runs the identical function, so this gate is
    /// perf-only.
    par_ok: bool,
    /// Per-shard `ServerCheck` gather and per-event propose results,
    /// reused across wave segments.
    scratch_checks: Vec<Vec<ShardCheck>>,
    scratch_proposed: Vec<Option<Vec<RunEntry>>>,

    pub(super) report: SimReport,
    total: ResVec,

    /// Fault layer (module docs, §Faults). `down[l]` marks a crashed
    /// server, `saved_cap[l]` holds its nominal capacity while the
    /// live one is zeroed. All four vectors stay empty-of-effect when
    /// the plan is empty — `has_faults` gates every hot-path touch.
    pub(super) down: Vec<bool>,
    pub(super) saved_cap: Vec<ResVec>,
    /// Per-user queues of retries whose backoff has expired, consumed
    /// ahead of fresh arena tasks by [`EngineCtx::place`].
    pub(super) retry_ready: Vec<VecDeque<RetryTask>>,
    /// Slab of in-flight (backoff-pending) retry payloads addressed
    /// by [`EventKind::Retry`] slots, with a LIFO free list.
    pub(super) retry_pending: Vec<RetryTask>,
    pub(super) retry_free: Vec<u32>,
    /// True iff the plan schedules at least one transition.
    pub(super) has_faults: bool,
    /// Outage records in `report.outages` not yet marked recovered.
    unresolved_outages: usize,

    /// Churn layer (module docs, §Churn). `present[u]` is the user's
    /// live presence (all-true under an empty plan); `user_epoch[u]`
    /// counts the user's departures, stamped into retry payloads so
    /// a leave strands the in-flight ones. `has_churn` gates every
    /// hot-path touch, mirroring `has_faults`.
    pub(super) present: Vec<bool>,
    pub(super) user_epoch: Vec<u32>,
    pub(super) has_churn: bool,
    /// Running tasks evicted by departures (a subset of
    /// `report.tasks_abandoned`): like fault evictions, they left the
    /// PS without completing, so the auditor's placed-minus-completed
    /// balance subtracts them separately.
    pub(super) churn_evicted: usize,

    /// Wave-boundary invariant auditor state; `Some` iff auditing is
    /// on ([`SimOpts::audit`] or `DRFH_AUDIT=1`). See
    /// [`crate::sim::audit`].
    pub(super) audit: Option<super::audit::AuditState>,
}

impl<'a> Simulation<'a> {
    /// Build a simulation for `trace` on `cluster` under `scheduler`.
    pub fn new(
        cluster: Cluster,
        trace: &'a Trace,
        mut scheduler: Box<dyn Scheduler + 'a>,
        opts: SimOpts,
    ) -> Self {
        trace.validate().expect("invalid trace");
        let total = cluster.total_capacity();
        let m = cluster.dims();
        let arena = TaskArena::new(trace);
        // per-task constants derived once per *distinct* demand row
        // (bit-identical to the per-user computation they replace)
        let dom_deltas: Vec<f64> =
            arena.demands().per_user(|d| d.div(&total).max());
        // blocked-user fit keys: min_r demand_r per interned class,
        // with the user -> class map (see BlockedIndex docs)
        let class_fit: Vec<f64> = (0..arena.demands().classes())
            .map(|c| arena.demands().row(c).min())
            .collect();
        let class_of = arena.demands().class_map().to_vec();
        let users: Vec<UserState> = trace
            .users
            .iter()
            .zip(&dom_deltas)
            .map(|(u, &dom_delta)| UserState {
                demand: u.demand,
                weight: u.weight,
                pending: 0,
                running: 0,
                dom_share: 0.0,
                usage: ResVec::zeros(m),
                dom_delta,
            })
            .collect();
        let n = users.len();
        let k = cluster.len();
        let name = scheduler.name().to_string();
        let nshards = opts.shards.resolve(k);
        let spec = ShardSpec::contiguous(k, nshards);
        // placement indexes mirror the shard layout (per-shard heaps
        // reconciled by a cross-shard argmin, same selections)
        scheduler.on_topology(nshards);
        let par_ok = nshards > 1
            && std::env::var_os("DRFH_SEQ").is_none()
            && std::thread::available_parallelism()
                .map(|p| p.get() > 1)
                .unwrap_or(false);
        let events = match opts.queue {
            QueueKind::Auto => {
                // perf-only: any geometry drains in the same total
                // (time, seq) order (see `wheel` docs); all lanes
                // share the one auto-tuned geometry
                let (width, nb) = wheel::auto_geometry(
                    trace
                        .jobs
                        .iter()
                        .flat_map(|j| j.tasks.iter().map(|t| t.duration)),
                );
                ShardedQueue::from_fn(nshards, || {
                    SimQueue::Wheel(TimerWheel::with_params(width, nb))
                })
            }
            kind => Events::new(kind, nshards),
        };
        let sketch_budget = opts.share_sketch;
        // same env-override convention as DRFH_SEQ: the CI smoke and
        // ad-hoc reproduction runs flip auditing on without touching
        // any call site
        let audit_on =
            opts.audit || std::env::var_os("DRFH_AUDIT").is_some();

        let mut sim = Simulation {
            cluster,
            users,
            scheduler,
            opts: opts.clone(),
            queues: vec![VecDeque::new(); n],
            arena,
            servers: (0..k).map(|_| ServerSim::new()).collect(),
            events,
            seq: 0,
            now: 0.0,
            eligible: vec![true; n],
            blocked: BlockedIndex::classed(class_of, class_fit),
            scratch_unblock: Vec::new(),
            scratch_classes: Vec::new(),
            spec,
            par_ok,
            scratch_checks: vec![Vec::new(); nshards],
            scratch_proposed: Vec::new(),
            report: SimReport {
                scheduler: name,
                cpu_util: TimeSeries::default(),
                mem_util: TimeSeries::default(),
                user_dom_share: vec![TimeSeries::default(); if opts.track_user_series { n } else { 0 }],
                share_sketches: match sketch_budget {
                    Some(budget) => {
                        vec![ShareSketch::with_budget(budget); n]
                    }
                    None => Vec::new(),
                },
                user_cpu_share: vec![TimeSeries::default(); if opts.track_user_series { n } else { 0 }],
                user_mem_share: vec![TimeSeries::default(); if opts.track_user_series { n } else { 0 }],
                jobs: Vec::new(),
                job_stats: JobStats::default(),
                user_tasks: vec![UserTaskCounts::default(); n],
                tasks_placed: 0,
                tasks_completed: 0,
                avg_cpu_util: 0.0,
                avg_mem_util: 0.0,
                goodput_s: 0.0,
                wasted_s: 0.0,
                evictions: 0,
                retries: 0,
                tasks_lost: 0,
                outages: Vec::new(),
                user_joins: 0,
                user_leaves: 0,
                tasks_abandoned: 0,
                abandoned_s: 0.0,
            },
            total,
            down: vec![false; k],
            saved_cap: vec![ResVec::zeros(m); k],
            retry_ready: vec![VecDeque::new(); n],
            retry_pending: Vec::new(),
            retry_free: Vec::new(),
            has_faults: !opts.faults.events.is_empty(),
            unresolved_outages: 0,
            present: vec![true; n],
            user_epoch: vec![0; n],
            has_churn: !opts.churn.is_empty(),
            churn_evicted: 0,
            audit: audit_on.then(super::audit::AuditState::new),
        };
        // initial absentees consume no events and no seq — applied
        // before anything is pushed, exactly like capacity layout
        for &u in &opts.churn.absent_at_start {
            assert!(u < n, "churn plan names user {u} of {n}");
            sim.present[u] = false;
            sim.eligible[u] = false;
        }
        for (j, job) in trace.jobs.iter().enumerate() {
            if job.submit <= opts.horizon {
                sim.push_event(job.submit, EventKind::Arrival(j));
            }
        }
        sim.push_event(0.0, EventKind::Sample);
        // fault transitions last: an empty plan pushes nothing, so
        // every pre-existing event keeps the seq it had before this
        // layer existed — the FaultPlan::none() parity guarantee
        for ev in &opts.faults.events {
            assert!(ev.server < k, "fault plan names server {} of {k}", ev.server);
            if ev.time <= opts.horizon {
                let kind = if ev.up {
                    EventKind::ServerUp { server: ev.server }
                } else {
                    EventKind::ServerDown { server: ev.server }
                };
                sim.push_event(ev.time.max(0.0), kind);
            }
        }
        // churn transitions after the fault ones: the same
        // empty-plan guarantee — ChurnPlan::none() pushes nothing
        // and marks nobody absent, so seq assignment (and every
        // decision) matches the pre-churn engine
        for ev in &opts.churn.events {
            assert!(ev.user < n, "churn plan names user {} of {n}", ev.user);
            if ev.time <= opts.horizon {
                let kind = if ev.join {
                    EventKind::UserJoin { user: ev.user }
                } else {
                    EventKind::UserLeave { user: ev.user }
                };
                sim.push_event(ev.time.max(0.0), kind);
            }
        }
        sim
    }

    fn push_event(&mut self, time: f64, kind: EventKind) {
        push_event_into(
            &mut self.events,
            &self.spec,
            &mut self.seq,
            time,
            kind,
        );
    }

    /// Run to completion (horizon or event exhaustion) and return the
    /// report.
    ///
    /// All events sharing a timestamp are applied *before* the
    /// scheduler runs, so simultaneous arrivals compete fairly
    /// (progressive filling sees every queued task, not an accident of
    /// event ordering). With more than one shard the identical wave
    /// structure runs through the propose/commit split
    /// ([`Simulation::run_sharded`]); the single-shard path below is
    /// the sequential engine and the parity reference.
    pub fn run(mut self) -> SimReport {
        if self.spec.shards() > 1 {
            return self.run_sharded();
        }
        while let Some(ev) = self.events.pop() {
            self.audit_note(ev.time, ev.seq);
            if ev.time > self.opts.horizon {
                break;
            }
            self.now = ev.time;
            let mut need_sched = self.apply(ev.payload);
            while let Some(next) = self.events.peek() {
                if next.time > self.now {
                    break;
                }
                let next = self.events.pop().unwrap();
                self.audit_note(next.time, next.seq);
                need_sched |= self.apply(next.payload);
            }
            if need_sched {
                self.schedule_loop();
            }
            self.audit_wave();
        }
        self.report.avg_cpu_util = self.report.cpu_util.time_avg();
        self.report.avg_mem_util = self.report.mem_util.time_avg();
        self.report
    }

    /// Apply one event's state changes; returns true when a scheduling
    /// opportunity arises (arrival or completion).
    fn apply(&mut self, kind: EventKind) -> bool {
        match kind {
            EventKind::Arrival(j) => self.on_arrival(j),
            EventKind::ServerCheck { server, gen } => {
                self.on_server_check(server, gen)
            }
            EventKind::Sample => {
                self.on_sample();
                false
            }
            EventKind::ServerDown { server } => {
                self.on_server_down_ev(server)
            }
            EventKind::ServerUp { server } => self.on_server_up_ev(server),
            EventKind::Retry { slot } => self.on_retry(slot),
            EventKind::UserJoin { user } => self.on_user_join_ev(user),
            EventKind::UserLeave { user } => self.on_user_leave_ev(user),
        }
    }

    fn on_arrival(&mut self, j: usize) -> bool {
        let user = self.arena.job_user(j);
        if self.has_churn && !self.present[user] {
            // an absent user's job never enters the system; counted
            // so completion ratios reflect the churn (module docs,
            // §Churn — measured degradation, not an error)
            let num_tasks = self.arena.job_len(j);
            self.report.user_tasks[user].submitted += num_tasks;
            self.report.tasks_abandoned += num_tasks;
            return false;
        }
        self.queues[user].push_back(j as u32);
        let num_tasks = self.arena.job_len(j);
        self.users[user].pending += num_tasks;
        self.report.user_tasks[user].submitted += num_tasks;
        // a blocked user stays blocked (its demand is static); for the
        // rest, let indexed policies re-insert the user
        if !self.blocked.is_blocked(user) {
            self.scheduler.on_ready(user);
        }
        true
    }

    fn on_server_check(&mut self, l: usize, gen: u64) -> bool {
        if self.servers[l].gen != gen {
            return false; // stale event
        }
        self.servers[l].advance(self.now);
        let mut completed_any = false;
        while let Some(top) = self.servers[l].running.peek() {
            if top.vfinish <= self.servers[l].vtime + 1e-9 {
                let entry = self.servers[l].running.pop().unwrap();
                self.complete_task(l, entry);
                completed_any = true;
            } else {
                break;
            }
        }
        self.refresh_server(l);
        if completed_any {
            self.unblock_for_server(l);
        }
        completed_any
    }

    /// Spread (max − min) of weighted dominant shares across *active*
    /// users (running or pending work) — the envy measure behind
    /// fairness-recovery records (module docs, §Faults).
    fn envy_spread(&self) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for us in &self.users {
            if us.running + us.pending == 0 {
                continue;
            }
            let key = us.share_key();
            lo = lo.min(key);
            hi = hi.max(key);
        }
        if hi >= lo {
            hi - lo
        } else {
            0.0
        }
    }

    /// `ServerDown`: evict every running entry on `l` (remaining work
    /// re-queued under the retry policy or counted lost), zero the
    /// server's capacity, and stale its PS generation. Idempotent —
    /// a crash of an already-down server is a no-op (plans built by
    /// [`FaultPlan::from_intervals`] never produce one, hand-built
    /// plans might). Never a scheduling opportunity: capacity only
    /// shrank and no task became pending *now* (retries arrive
    /// later, after backoff).
    fn on_server_down_ev(&mut self, l: usize) -> bool {
        if self.down[l] {
            return false;
        }
        // pre-crash fairness baseline, before any eviction moves it
        let baseline_envy = self.envy_spread();
        self.servers[l].advance(self.now);
        let vtime = self.servers[l].vtime;
        let mut running = std::mem::take(&mut self.servers[l].running);
        // drain in (vfinish, seq) heap order: deterministic retry
        // slot/seq assignment at every shard count
        while let Some(entry) = running.pop() {
            let u = entry.user as usize;
            let demand = self.users[u].demand;
            self.cluster.servers[l].release(&demand);
            self.cluster.servers[l].tasks -= 1;
            self.scheduler.on_complete(u, l);
            self.users[u].running -= 1;
            self.users[u].dom_share =
                self.users[u].running as f64 * self.users[u].dom_delta;
            self.users[u].usage.sub_assign(&demand);
            self.report.evictions += 1;
            let remaining = (entry.vfinish - vtime).max(0.0);
            self.report.wasted_s += (entry.dur - remaining).max(0.0);
            if entry.attempt < self.opts.retry.attempt_cap() {
                let rt = RetryTask {
                    job: entry.job,
                    attempt: entry.attempt,
                    task: entry.task,
                    remaining,
                    epoch: self.user_epoch[u],
                };
                let slot = match self.retry_free.pop() {
                    Some(s) => {
                        self.retry_pending[s as usize] = rt;
                        s
                    }
                    None => {
                        self.retry_pending.push(rt);
                        (self.retry_pending.len() - 1) as u32
                    }
                };
                let delay = self.opts.retry.backoff(
                    self.opts.faults.seed,
                    entry.task,
                    entry.attempt,
                );
                self.push_event(
                    self.now + delay,
                    EventKind::Retry { slot },
                );
            } else {
                self.report.tasks_lost += 1;
            }
        }
        self.servers[l].running = running;
        self.scheduler.on_server_down(l);
        self.down[l] = true;
        self.saved_cap[l] = self.cluster.servers[l].capacity;
        self.cluster.servers[l].capacity =
            ResVec::zeros(self.cluster.dims());
        // stale every queued check; pin the PS clock at a sane rate
        // (usage/capacity is 0/0 while down — never ask `rate()`)
        let srv = &mut self.servers[l];
        srv.gen += 1;
        srv.rate = 1.0;
        srv.t_last = self.now;
        self.report.outages.push(OutageRecord {
            at: self.now,
            server: l,
            baseline_envy,
            recovered_at: None,
        });
        self.unresolved_outages += 1;
        false
    }

    /// `ServerUp`: restore the saved capacity, re-arm the PS state
    /// (the next placement schedules the next check), tell the policy,
    /// and re-probe blocked users exactly like after a completion.
    fn on_server_up_ev(&mut self, l: usize) -> bool {
        if !self.down[l] {
            return false;
        }
        self.down[l] = false;
        self.cluster.servers[l].capacity = self.saved_cap[l];
        let srv = &mut self.servers[l];
        srv.t_last = self.now;
        srv.gen += 1;
        srv.rate = self.cluster.servers[l].rate();
        self.scheduler.on_server_up(l);
        self.unblock_for_server(l);
        true
    }

    /// `Retry`: the backoff expired — move the slab payload into the
    /// user's ready queue and announce the user like an arrival does.
    fn on_retry(&mut self, slot: u32) -> bool {
        let rt = self.retry_pending[slot as usize];
        self.retry_free.push(slot);
        let u = self.arena.job_user(rt.job as usize);
        // a departure since the eviction stranded this payload: every
        // UserLeave bumps the user's epoch, so a stale stamp means
        // the task's job was discarded wholesale — abandon it, even
        // if the user has since rejoined (module docs, §Churn)
        if self.has_churn && rt.epoch != self.user_epoch[u] {
            self.report.tasks_abandoned += 1;
            return false;
        }
        self.retry_ready[u].push_back(rt);
        self.users[u].pending += 1;
        self.report.retries += 1;
        if !self.blocked.is_blocked(u) {
            self.scheduler.on_ready(u);
        }
        true
    }

    /// `UserJoin`: re-admit `u` with a clean slate (module docs,
    /// §Churn). A departed user was dropped from the blocked set on
    /// its way out (and an initial absentee never entered it), so it
    /// re-enters schedulable directly. Idempotent — a join of a
    /// present user is a no-op (canonical plans never contain one).
    /// Pending work at join time is possible only when an arrival
    /// shares the timestamp and a smaller seq; announce it like an
    /// arrival would.
    fn on_user_join_ev(&mut self, u: usize) -> bool {
        if self.present[u] {
            return false;
        }
        self.present[u] = true;
        self.report.user_joins += 1;
        self.eligible[u] = true;
        self.scheduler.on_user_join(u);
        if self.users[u].pending > 0 {
            self.scheduler.on_ready(u);
            return true;
        }
        false
    }

    /// `UserLeave`: `u` departs (module docs, §Churn) — evict its run
    /// entries from every server (each heap drained in
    /// `(vfinish, seq)` order, rebuilt without them: deterministic at
    /// every shard count), release the capacity, discard its queued
    /// and retry-ready work, bump its retry epoch (stranding
    /// in-flight backoff payloads), drop it from the blocked set, and
    /// notify the policy. Idempotent — a leave of an absent user is a
    /// no-op. Freed capacity is a scheduling opportunity for the
    /// remaining users, re-probed exactly like after a completion.
    fn on_user_leave_ev(&mut self, u: usize) -> bool {
        if !self.present[u] {
            return false;
        }
        self.present[u] = false;
        self.user_epoch[u] += 1;
        self.report.user_leaves += 1;
        let mut touched: Vec<usize> = Vec::new();
        if self.users[u].running > 0 {
            for l in 0..self.cluster.len() {
                if !self.servers[l]
                    .running
                    .iter()
                    .any(|e| e.user as usize == u)
                {
                    continue;
                }
                self.servers[l].advance(self.now);
                let vtime = self.servers[l].vtime;
                let mut running =
                    std::mem::take(&mut self.servers[l].running);
                let mut kept =
                    BinaryHeap::with_capacity(running.len());
                while let Some(entry) = running.pop() {
                    if entry.user as usize != u {
                        kept.push(entry);
                        continue;
                    }
                    let demand = self.users[u].demand;
                    self.cluster.servers[l].release(&demand);
                    self.cluster.servers[l].tasks -= 1;
                    self.scheduler.on_free(l);
                    self.scheduler.on_complete(u, l);
                    self.users[u].running -= 1;
                    self.users[u].dom_share = self.users[u].running
                        as f64
                        * self.users[u].dom_delta;
                    self.users[u].usage.sub_assign(&demand);
                    self.report.tasks_abandoned += 1;
                    self.churn_evicted += 1;
                    let remaining = (entry.vfinish - vtime).max(0.0);
                    self.report.abandoned_s +=
                        (entry.dur - remaining).max(0.0);
                }
                self.servers[l].running = kept;
                // rate drops with the lighter load; the gen bump
                // stales queued checks and reschedules the next one
                self.refresh_server(l);
                touched.push(l);
            }
        }
        // queued + retry-ready work is exactly the user's pending
        // count (audited invariant), discarded wholesale
        self.report.tasks_abandoned += self.users[u].pending;
        self.users[u].pending = 0;
        self.queues[u].clear();
        self.retry_ready[u].clear();
        if self.blocked.is_blocked(u) {
            self.blocked.remove(u);
        }
        self.eligible[u] = false;
        self.scheduler.on_user_leave(u);
        for &l in &touched {
            self.unblock_for_server(l);
        }
        !touched.is_empty()
    }

    fn complete_task(&mut self, l: usize, entry: RunEntry) {
        let demand = self.users[entry.user as usize].demand;
        self.cluster.servers[l].release(&demand);
        self.cluster.servers[l].tasks -= 1;
        self.commit_completion(l, entry);
    }

    /// The cross-cutting half of a task completion — everything except
    /// the capacity release, which the caller has already applied
    /// ([`Simulation::complete_task`] on the sequential path,
    /// [`propose_shard`] on the sharded one). Statement order matches
    /// the pre-split `complete_task` exactly.
    fn commit_completion(&mut self, l: usize, entry: RunEntry) {
        let u = entry.user as usize;
        let demand = self.users[u].demand;
        self.scheduler.on_free(l);
        self.scheduler.on_complete(u, l);
        self.users[u].running -= 1;
        // Recompute, never accumulate: repeated `+= dom_delta` /
        // `-= dom_delta` cycles drift (float addition is not exactly
        // invertible), biasing the very key schedulers sort by. The
        // product form is exact for any running count and needs no
        // negative clamp.
        self.users[u].dom_share =
            self.users[u].running as f64 * self.users[u].dom_delta;
        self.users[u].usage.sub_assign(&demand);
        self.report.tasks_completed += 1;
        // the completing attempt's service demand is exactly the work
        // delivered (a retried task carries only its remaining work,
        // so crash-lost progress never double-counts here)
        self.report.goodput_s += entry.dur;
        self.report.user_tasks[u].completed += 1;
        let j = entry.job as usize;
        if self.arena.complete_one(j) {
            let submit = self.arena.job_submit(j);
            let num_tasks = self.arena.job_len(j);
            self.report.job_stats.record(self.now - submit, num_tasks);
            if self.opts.metrics == MetricsMode::Full {
                self.report.jobs.push(JobRecord {
                    job: j,
                    user: self.arena.job_user(j),
                    num_tasks,
                    submit,
                    finish: self.now,
                });
            }
        }
    }

    /// Recompute a server's PS rate and (re)schedule its next
    /// completion check.
    fn refresh_server(&mut self, l: usize) {
        refresh_server_at(
            &self.cluster,
            &mut self.servers,
            &mut self.events,
            &self.spec,
            &mut self.seq,
            self.now,
            l,
        );
    }

    /// Re-check blocked users against server `l` after it freed
    /// capacity. Candidate *classes* are pre-filtered by the
    /// BlockedIndex necessary condition (min demand component vs.
    /// `l`'s smallest headroom), and one exact `can_fit` probe per
    /// class decides all of its blocked members at once (the
    /// [`Scheduler::can_fit`] contract: the verdict depends on the
    /// user only through its demand class) — O(classes) probes per
    /// completion, however many users are blocked. The unblocked
    /// *set* matches the seed's full per-user scan. The headroom
    /// filter is only sound for demand-based `can_fit`;
    /// overcommitting policies (Slots — slot-based fits, headroom may
    /// be negative) consider every blocked class, as before.
    fn unblock_for_server(&mut self, l: usize) {
        if self.blocked.is_empty() {
            return;
        }
        let free_min = if self.scheduler.allows_overcommit() {
            f64::INFINITY
        } else {
            self.cluster.servers[l].min_headroom() + crate::cluster::FIT_EPS
        };
        let mut classes = std::mem::take(&mut self.scratch_classes);
        classes.clear();
        classes.extend(self.blocked.candidate_classes(free_min));
        let mut cands = std::mem::take(&mut self.scratch_unblock);
        cands.clear();
        for &c in &classes {
            let probe = self
                .blocked
                .class_members(c)
                .next()
                .expect("candidate class has a blocked member");
            if self.scheduler.can_fit(&self.cluster, &self.users, probe, l) {
                cands.extend(self.blocked.class_members(c));
            }
        }
        for &u in &cands {
            self.blocked.remove(u);
            self.eligible[u] = true;
            self.scheduler.on_ready(u);
        }
        self.scratch_unblock = cands;
        self.scratch_classes = classes;
    }

    /// One scheduling opportunity: hand the whole event wave to the
    /// policy through [`Scheduler::drain`]. The [`EngineCtx`] borrows
    /// every engine field except the scheduler itself, so the policy
    /// can read post-commit state and commit further decisions while
    /// it holds the ctx.
    fn schedule_loop(&mut self) {
        let overcommit = self.scheduler.allows_overcommit();
        let mut ctx = EngineCtx {
            cluster: &mut self.cluster,
            users: &mut self.users,
            eligible: &mut self.eligible,
            blocked: &mut self.blocked,
            queues: &mut self.queues,
            arena: &mut self.arena,
            servers: &mut self.servers,
            events: &mut self.events,
            spec: &self.spec,
            seq: &mut self.seq,
            now: self.now,
            report: &mut self.report,
            retry_ready: &mut self.retry_ready,
            overcommit,
        };
        self.scheduler.drain(&mut ctx);
    }

    fn on_sample(&mut self) {
        let util = self.cluster.utilization();
        self.report.cpu_util.push(self.now, util[0]);
        if self.cluster.dims() > 1 {
            self.report.mem_util.push(self.now, util[1]);
        }
        if self.opts.track_user_series {
            for (u, us) in self.users.iter().enumerate() {
                self.report.user_dom_share[u].push(self.now, us.dom_share);
                self.report.user_cpu_share[u]
                    .push(self.now, us.usage[0] / self.total[0]);
                if self.cluster.dims() > 1 {
                    self.report.user_mem_share[u]
                        .push(self.now, us.usage[1] / self.total[1]);
                }
            }
        }
        if self.opts.share_sketch.is_some() {
            for (u, us) in self.users.iter().enumerate() {
                self.report.share_sketches[u].push(self.now, us.dom_share);
            }
        }
        if let MetricsMode::Streaming { series_cap } = self.opts.metrics {
            self.report.cpu_util.enforce_cap(series_cap);
            self.report.mem_util.enforce_cap(series_cap);
            if self.opts.track_user_series {
                for u in 0..self.users.len() {
                    self.report.user_dom_share[u].enforce_cap(series_cap);
                    self.report.user_cpu_share[u].enforce_cap(series_cap);
                    self.report.user_mem_share[u].enforce_cap(series_cap);
                }
            }
        }
        // fairness-recovery resolution (module docs, §Faults): close
        // every open outage whose envy spread is back inside its
        // pre-crash baseline + ε. Gated so fault-free runs never even
        // compute the spread.
        if self.has_faults && self.unresolved_outages > 0 {
            let spread = self.envy_spread();
            let eps = self.opts.faults.envy_eps;
            for rec in &mut self.report.outages {
                if rec.recovered_at.is_none()
                    && spread <= rec.baseline_envy + eps
                {
                    rec.recovered_at = Some(self.now);
                    self.unresolved_outages -= 1;
                }
            }
        }
        let next = self.now + self.opts.sample_dt;
        if next <= self.opts.horizon {
            self.push_event(next, EventKind::Sample);
        }
    }

    // ------------------------------------------------- sharded drain

    /// The `S >= 2` main loop (§Perf: sharded data plane). Wave
    /// structure is identical to [`Simulation::run`]: gather every
    /// event at `now`, apply them all, then let the scheduler drain
    /// once. The gather is batched rather than interleaved, which is
    /// order-preserving because any event pushed *during* a wave
    /// carries a larger seq than everything already queued (seq is a
    /// monotone push counter) — the sequential loop would also drain
    /// it after the pre-existing same-time events. The inner loop
    /// re-gathers defensively in case an applied event scheduled
    /// another at the same timestamp.
    fn run_sharded(mut self) -> SimReport {
        let mut wave: Vec<Event> = Vec::new();
        while let Some(ev) = self.events.pop() {
            self.audit_note(ev.time, ev.seq);
            if ev.time > self.opts.horizon {
                break;
            }
            self.now = ev.time;
            let mut need_sched = false;
            wave.clear();
            wave.push(ev);
            loop {
                while let Some(next) = self.events.peek() {
                    if next.time > self.now {
                        break;
                    }
                    let next = self.events.pop().unwrap();
                    self.audit_note(next.time, next.seq);
                    wave.push(next);
                }
                need_sched |= self.apply_wave(&wave);
                wave.clear();
                match self.events.peek() {
                    Some(next) if next.time <= self.now => {}
                    _ => break,
                }
            }
            if need_sched {
                self.schedule_loop();
            }
            self.audit_wave();
        }
        self.report.avg_cpu_util = self.report.cpu_util.time_avg();
        self.report.avg_mem_util = self.report.mem_util.time_avg();
        self.report
    }

    /// Apply one same-timestamp wave: samples are barriers (they read
    /// whole-cluster utilization mid-wave, so every earlier release
    /// must be visible and no later one may be), splitting the wave
    /// into segments that each run propose + commit. Fault
    /// transitions are barriers too: a `ServerDown`/`ServerUp`
    /// bumps the PS generation, so a same-wave `ServerCheck` sorting
    /// *after* it must observe the bump (be stale) while one sorting
    /// *before* must not — exactly the sequential order a propose
    /// batch would blur. Faults are rare next to checks, so the extra
    /// segment splits cost nothing measurable.
    fn apply_wave(&mut self, wave: &[Event]) -> bool {
        let is_barrier = |kind: &EventKind| {
            matches!(
                kind,
                EventKind::Sample
                    | EventKind::ServerDown { .. }
                    | EventKind::ServerUp { .. }
                    | EventKind::UserJoin { .. }
                    | EventKind::UserLeave { .. }
            )
        };
        let mut need = false;
        let mut i = 0;
        while i < wave.len() {
            match wave[i].payload {
                EventKind::Sample => {
                    self.on_sample();
                    i += 1;
                    continue;
                }
                EventKind::ServerDown { server } => {
                    need |= self.on_server_down_ev(server);
                    i += 1;
                    continue;
                }
                EventKind::ServerUp { server } => {
                    need |= self.on_server_up_ev(server);
                    i += 1;
                    continue;
                }
                // churn transitions are barriers for the same reason
                // as faults: a leave mutates run-entry heaps across
                // all shards, so same-wave checks must order
                // strictly against it (module docs, §Churn)
                EventKind::UserJoin { user } => {
                    need |= self.on_user_join_ev(user);
                    i += 1;
                    continue;
                }
                EventKind::UserLeave { user } => {
                    need |= self.on_user_leave_ev(user);
                    i += 1;
                    continue;
                }
                _ => {}
            }
            let mut j = i + 1;
            while j < wave.len() && !is_barrier(&wave[j].payload) {
                j += 1;
            }
            need |= self.apply_segment(&wave[i..j]);
            i = j;
        }
        need
    }

    /// One sample-free segment of a wave, in two phases (module docs,
    /// §Perf: sharded data plane):
    ///
    /// * **propose** — [`propose_shard`] per shard, on scoped worker
    ///   threads when the segment is heavy enough to amortize the
    ///   spawns (the inline path runs the identical function);
    /// * **commit** — sequential replay in `(time, seq)` order through
    ///   the same code the sequential engine runs.
    ///
    /// A live check with zero completions still commits: the
    /// sequential path refreshes such a server unconditionally
    /// (generation bump plus a seq-consuming next-check push), and seq
    /// assignment must match event for event.
    fn apply_segment(&mut self, seg: &[Event]) -> bool {
        // gather ServerChecks by owner shard
        let ns = self.spec.shards();
        for checks in &mut self.scratch_checks {
            checks.clear();
        }
        let mut n_checks = 0usize;
        for (i, ev) in seg.iter().enumerate() {
            if let EventKind::ServerCheck { server, gen } = ev.payload {
                self.scratch_checks[self.spec.owner_of(server)]
                    .push((i as u32, server as u32, gen));
                n_checks += 1;
            }
        }

        // propose: shard-local completion pops. `mem::take` keeps the
        // split-off column slices at the full borrow lifetime so they
        // can cross into the scoped threads.
        self.scratch_proposed.clear();
        self.scratch_proposed.resize_with(seg.len(), || None);
        if n_checks > 0 {
            let spec = &self.spec;
            let users = &self.users;
            let now = self.now;
            let checks = &self.scratch_checks;
            let proposed = &mut self.scratch_proposed;
            let mut srv_rest: &mut [Server] = &mut self.cluster.servers;
            let mut sim_rest: &mut [ServerSim] = &mut self.servers;
            if self.par_ok && n_checks >= PAR_MIN_CHECKS {
                std::thread::scope(|sc| {
                    let mut handles = Vec::with_capacity(ns);
                    for s in 0..ns {
                        let len = spec.len_of(s);
                        let (srv, rest) =
                            std::mem::take(&mut srv_rest).split_at_mut(len);
                        srv_rest = rest;
                        let (sim, rest) =
                            std::mem::take(&mut sim_rest).split_at_mut(len);
                        sim_rest = rest;
                        if checks[s].is_empty() {
                            continue;
                        }
                        let base = spec.start_of(s);
                        let shard_checks = &checks[s];
                        handles.push(sc.spawn(move || {
                            propose_shard(
                                srv, sim, base, users, now, shard_checks,
                            )
                        }));
                    }
                    // join in shard order; results scatter by segment
                    // index, so completion timing cannot reorder them
                    for h in handles {
                        for (idx, entries) in
                            h.join().expect("shard propose worker")
                        {
                            proposed[idx as usize] = Some(entries);
                        }
                    }
                });
            } else {
                for s in 0..ns {
                    let len = spec.len_of(s);
                    let (srv, rest) =
                        std::mem::take(&mut srv_rest).split_at_mut(len);
                    srv_rest = rest;
                    let (sim, rest) =
                        std::mem::take(&mut sim_rest).split_at_mut(len);
                    sim_rest = rest;
                    if checks[s].is_empty() {
                        continue;
                    }
                    for (idx, entries) in propose_shard(
                        srv,
                        sim,
                        spec.start_of(s),
                        users,
                        now,
                        &checks[s],
                    ) {
                        proposed[idx as usize] = Some(entries);
                    }
                }
            }
        }

        // commit: sequential replay in (time, seq) order
        let mut proposed = std::mem::take(&mut self.scratch_proposed);
        let mut need = false;
        for (i, ev) in seg.iter().enumerate() {
            match ev.payload {
                EventKind::Arrival(j) => need |= self.on_arrival(j),
                EventKind::ServerCheck { server, .. } => {
                    if let Some(entries) = proposed[i].take() {
                        let completed_any = !entries.is_empty();
                        for entry in entries {
                            self.commit_completion(server, entry);
                        }
                        self.refresh_server(server);
                        if completed_any {
                            self.unblock_for_server(server);
                            need = true;
                        }
                    }
                }
                // the backoff payload is engine-global (slab + user
                // queue), not shard-local — replayed sequentially in
                // seq order exactly like an arrival
                EventKind::Retry { slot } => need |= self.on_retry(slot),
                EventKind::Sample
                | EventKind::ServerDown { .. }
                | EventKind::ServerUp { .. }
                | EventKind::UserJoin { .. }
                | EventKind::UserLeave { .. } => {
                    unreachable!("samples, fault transitions and \
                                  churn transitions are segment \
                                  barriers")
                }
            }
        }
        self.scratch_proposed = proposed;
        need
    }
}

// ------------------------------------------------------- drain plumbing

fn push_event_into(
    events: &mut Events,
    spec: &ShardSpec,
    seq: &mut u64,
    time: f64,
    kind: EventKind,
) {
    *seq += 1;
    // each ServerCheck rides its owner shard's lane so shard-local
    // pushes stay shard-local; arrivals and samples ride lane 0. Lane
    // routing is ownership/locality only — the merge cursor restores
    // the exact global (time, seq) order for any assignment
    // ([`wheel::ShardedQueue`]).
    let lane = match kind {
        EventKind::ServerCheck { server, .. }
        | EventKind::ServerDown { server }
        | EventKind::ServerUp { server } => spec.owner_of(server),
        EventKind::Arrival(_)
        | EventKind::Sample
        | EventKind::Retry { .. }
        | EventKind::UserJoin { .. }
        | EventKind::UserLeave { .. } => 0,
    };
    events.push_to(lane, Event { time, seq: *seq, payload: kind });
}

/// Recompute server `l`'s PS rate and (re)schedule its next completion
/// check — shared between the completion path ([`Simulation`] methods)
/// and the drain path ([`EngineCtx::place`]).
fn refresh_server_at(
    cluster: &Cluster,
    servers: &mut [ServerSim],
    events: &mut Events,
    spec: &ShardSpec,
    seq: &mut u64,
    now: f64,
    l: usize,
) {
    let srv = &mut servers[l];
    srv.rate = cluster.servers[l].rate();
    srv.gen += 1;
    if let Some(top) = srv.running.peek() {
        let dt = (top.vfinish - srv.vtime).max(0.0) / srv.rate;
        let eta = now + dt;
        let gen = srv.gen;
        push_event_into(events, spec, seq, eta, EventKind::ServerCheck {
            server: l,
            gen,
        });
    }
}

/// Shard-local half of a wave segment's `ServerCheck` work (§Perf:
/// sharded data plane): for each gathered check on this shard, skip it
/// if stale, otherwise advance the PS clock and pop every completed
/// [`RunEntry`], releasing its demand from the shard-owned [`Server`]
/// column. Mutates only this shard's slices (global server `l` lives
/// at `l - base`); the only shared reads are the static per-user
/// demand vectors, so concurrent shards never observe each other. The
/// completion pops and the release arithmetic are statement-for-
/// statement the sequential `on_server_check`/`complete_task` path —
/// the cross-cutting rest is replayed by the sequential commit.
///
/// Live checks are reported even with zero completions (the commit
/// must still refresh those servers to keep seq assignment aligned
/// with the sequential engine). At most one check per server can be
/// live in a segment: generations are unique per push, so only one
/// queued event ever matches the server's current generation.
fn propose_shard(
    cluster_servers: &mut [Server],
    servers: &mut [ServerSim],
    base: usize,
    users: &[UserState],
    now: f64,
    checks: &[ShardCheck],
) -> Vec<(u32, Vec<RunEntry>)> {
    let mut out = Vec::with_capacity(checks.len());
    for &(idx, server, gen) in checks {
        let sl = server as usize - base;
        if servers[sl].gen != gen {
            continue; // stale event, same guard as the sequential path
        }
        servers[sl].advance(now);
        let mut entries = Vec::new();
        while let Some(top) = servers[sl].running.peek() {
            if top.vfinish <= servers[sl].vtime + 1e-9 {
                let entry = servers[sl].running.pop().unwrap();
                let demand = users[entry.user as usize].demand;
                cluster_servers[sl].release(&demand);
                cluster_servers[sl].tasks -= 1;
                entries.push(entry);
            } else {
                break;
            }
        }
        out.push((idx, entries));
    }
    out
}

/// The engine's side of the batched-drain protocol: disjoint mutable
/// borrows of every [`Simulation`] field a placement touches, so the
/// scheduler (the one field *not* borrowed) can be called with the ctx.
struct EngineCtx<'e, 't> {
    cluster: &'e mut Cluster,
    users: &'e mut [UserState],
    eligible: &'e mut [bool],
    blocked: &'e mut BlockedIndex,
    queues: &'e mut [VecDeque<u32>],
    arena: &'e mut TaskArena<'t>,
    servers: &'e mut [ServerSim],
    events: &'e mut Events,
    spec: &'e ShardSpec,
    seq: &'e mut u64,
    now: f64,
    report: &'e mut SimReport,
    retry_ready: &'e mut [VecDeque<RetryTask>],
    overcommit: bool,
}

impl DrainCtx for EngineCtx<'_, '_> {
    fn cluster(&self) -> &Cluster {
        &*self.cluster
    }

    fn users(&self) -> &[UserState] {
        &*self.users
    }

    fn eligible(&self) -> &[bool] {
        &*self.eligible
    }

    /// Commit one task of `u` onto `l` (the pre-batching
    /// `Simulation::place`, minus the `on_place` echo — the deciding
    /// policy updates its own state).
    fn place(&mut self, u: usize, l: usize) {
        let demand = self.users[u].demand;
        if !self.overcommit {
            debug_assert!(
                self.cluster.servers[l].fits(&demand),
                "scheduler violated capacity"
            );
        }
        // retries first (their pending predates the fresh work), then
        // round-robin across the user's jobs: take one task from the
        // front job, then rotate it to the back if it has more. With
        // an empty fault plan the retry queue is always empty and
        // this is byte-for-byte the pre-fault path.
        let (j, duration, attempt, task) =
            match self.retry_ready[u].pop_front() {
                Some(rt) => (
                    rt.job as usize,
                    rt.remaining,
                    rt.attempt + 1,
                    Some(rt.task),
                ),
                None => {
                    let j = self.queues[u]
                        .pop_front()
                        .expect("placement without pending")
                        as usize;
                    let duration = self.arena.take_next(j);
                    if self.arena.unplaced(j) > 0 {
                        self.queues[u].push_back(j as u32);
                    }
                    (j, duration, 1, None)
                }
            };
        self.users[u].pending -= 1;
        self.users[u].running += 1;
        // recompute, never accumulate — see `complete_task`
        self.users[u].dom_share =
            self.users[u].running as f64 * self.users[u].dom_delta;
        self.users[u].usage.add_assign(&demand);
        self.cluster.servers[l].commit(&demand);
        self.cluster.servers[l].tasks += 1;
        self.report.tasks_placed += 1;

        self.servers[l].advance(self.now);
        *self.seq += 1;
        let entry = RunEntry {
            vfinish: self.servers[l].vtime + duration,
            seq: *self.seq,
            user: u as u32,
            job: j as u32,
            dur: duration,
            attempt,
            // a fresh task is named by its first placement's seq —
            // deterministic at every shard count, stable across
            // retries
            task: task.unwrap_or(*self.seq),
        };
        self.servers[l].running.push(entry);
        refresh_server_at(
            self.cluster,
            self.servers,
            self.events,
            self.spec,
            self.seq,
            self.now,
            l,
        );
    }

    fn block(&mut self, u: usize) {
        self.blocked.insert(u);
        self.eligible[u] = false;
    }
}

/// Convenience: build and run in one call.
pub fn run<'a>(
    cluster: Cluster,
    trace: &'a Trace,
    scheduler: Box<dyn Scheduler + 'a>,
    opts: SimOpts,
) -> SimReport {
    Simulation::new(cluster, trace, scheduler, opts).run()
}
