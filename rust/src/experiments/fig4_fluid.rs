//! Fig. 4 (fluid counterpart) — dynamic sharing under the *exact*
//! fluid DRFH allocation: three users with the Fig. 4 demand vectors
//! join a 100-server pool at t = 0, 200 and 500 s; the allocation is
//! re-equalized every [`DT`] seconds, user 1 drains a finite backlog
//! and departs, and the survivors rebalance upward — the fluid
//! trajectory the discrete Best-Fit run of [`super::fig4`]
//! approximates.
//!
//! The sweep runs two jobs on [`super::runner`]: the warm-started
//! [`IncrementalDrfh`] event path and the from-scratch
//! `allocator::solve` reference. Both produce the same share
//! trajectory (checked to solver precision in `max_share_err`); the
//! point of the pair is the cost gap, reported as simplex search
//! pivots (`warm_pivots` vs `scratch_pivots`) — the same numbers
//! `benches/allocator_scale.rs` records in `BENCH_allocator.json`.

use super::runner::{self, Job};
use super::write_csv;
use crate::allocator::incremental::{IncrementalDrfh, UserId};
use crate::allocator::{self, FluidUser};
use crate::cluster::{Cluster, ResVec};
use crate::util::Pcg32;

/// Re-equalization period (seconds of fluid time per allocate call).
pub const DT: f64 = 5.0;
/// Fluid horizon.
pub const HORIZON: f64 = 2_000.0;
/// Join times (paper Fig. 4).
pub const JOIN: [f64; 3] = [0.0, 200.0, 500.0];
/// User 1's backlog in task-seconds, sized so it drains around
/// t ≈ 1000 s under fair sharing (paper: departs at 1080 s).
pub const WORK_USER1: f64 = 90_000.0;

/// One backend's trajectory.
struct SimOut {
    /// Per-step dominant share per user (0 while absent).
    share: Vec<[f64; 3]>,
    /// Per-step fluid task allocation per user.
    tasks: Vec<[f64; 3]>,
    depart: Option<f64>,
    /// Simplex search pivots across the whole sweep.
    pivots: u64,
    /// LP solves (progressive-filling rounds) across the sweep.
    lp_solves: u64,
    /// Warm-started solves (incremental backend only).
    warm_solves: u64,
}

/// Measured sweep results.
#[derive(Clone, Debug)]
pub struct Fig4FluidResult {
    /// Per-step dominant share per user (incremental path).
    pub share: Vec<[f64; 3]>,
    /// Per-step fluid task allocation per user.
    pub tasks: Vec<[f64; 3]>,
    /// (label, window, per-user mean dominant share)
    pub phases: Vec<(String, (f64, f64), [f64; 3])>,
    /// user 1 departure time (backlog drained), if reached
    pub depart: Option<f64>,
    pub total_cpu: f64,
    pub total_mem: f64,
    /// Simplex search pivots: warm-started event path.
    pub warm_pivots: u64,
    /// Simplex search pivots: from-scratch re-solves.
    pub scratch_pivots: u64,
    /// LP solves on the warm path, and how many started warm.
    pub warm_lp_solves: u64,
    pub warm_started: u64,
    /// Max |warm − scratch| dominant-share divergence over the sweep.
    pub max_share_err: f64,
}

/// The Fig. 4 demand vectors (`workload::gen::fig4_trace`).
fn demands() -> [ResVec; 3] {
    [
        ResVec::cpu_mem(0.2, 0.3),
        ResVec::cpu_mem(0.5, 0.1),
        ResVec::cpu_mem(0.1, 0.3),
    ]
}

/// One fluid sweep: `warm` picks the incremental or from-scratch
/// backend; everything else (joins, backlog drain, departure) is
/// identical, so the trajectories must agree.
fn simulate(cluster: &Cluster, work1: f64, warm: bool) -> SimOut {
    let demand = demands();
    let steps = (HORIZON / DT) as usize;
    let mut out = SimOut {
        share: Vec::with_capacity(steps),
        tasks: Vec::with_capacity(steps),
        depart: None,
        pivots: 0,
        lp_solves: 0,
        warm_solves: 0,
    };
    // the standing LP skeleton is only built on the warm backend; the
    // scratch job must not pay (or time) its construction
    let mut inc = if warm {
        Some(IncrementalDrfh::new(cluster))
    } else {
        None
    };
    let mut ids: [Option<UserId>; 3] = [None; 3];
    let mut scratch: Vec<(usize, FluidUser)> = Vec::new();
    let mut joined = [false; 3];
    let mut departed = [false; 3];
    let mut remaining1 = work1;
    for s in 0..steps {
        let t = s as f64 * DT;
        for u in 0..3 {
            if !joined[u] && t + 1e-9 >= JOIN[u] {
                joined[u] = true;
                let fu = FluidUser {
                    demand: demand[u],
                    weight: 1.0,
                    task_cap: None,
                };
                if warm {
                    ids[u] = Some(inc.as_mut().unwrap().add_user(fu));
                } else {
                    scratch.push((u, fu));
                }
            }
        }
        // user 1 can run at most backlog/DT concurrent fluid tasks
        if joined[0] && !departed[0] {
            let cap = Some(remaining1 / DT);
            if warm {
                inc.as_mut().unwrap().set_cap(ids[0].unwrap(), cap);
            } else {
                for e in scratch.iter_mut() {
                    if e.0 == 0 {
                        e.1.task_cap = cap;
                    }
                }
            }
        }
        // re-equalize and record
        let mut share = [0.0f64; 3];
        let mut tasks = [0.0f64; 3];
        if warm {
            let a = inc.as_mut().unwrap().allocate();
            out.pivots += a.lp_pivots;
            out.lp_solves += a.lp_solves as u64;
            let present: Vec<usize> = (0..3)
                .filter(|&u| joined[u] && !departed[u])
                .collect();
            for (k, &u) in present.iter().enumerate() {
                share[u] = a.g[k];
                tasks[u] = a.tasks[k];
            }
        } else {
            let users: Vec<FluidUser> =
                scratch.iter().map(|(_, fu)| fu.clone()).collect();
            let a = allocator::solve(cluster, &users);
            out.pivots += a.lp_pivots;
            out.lp_solves += a.lp_solves as u64;
            for (k, &(u, _)) in scratch.iter().enumerate() {
                share[u] = a.g[k];
                tasks[u] = a.tasks[k];
            }
        }
        out.share.push(share);
        out.tasks.push(tasks);
        // drain user 1's backlog; depart when it empties
        if joined[0] && !departed[0] {
            remaining1 = (remaining1 - tasks[0] * DT).max(0.0);
            if remaining1 <= 1e-6 {
                departed[0] = true;
                out.depart = Some(t + DT);
                if warm {
                    inc.as_mut().unwrap().remove_user(ids[0].take().unwrap());
                } else {
                    scratch.retain(|&(u, _)| u != 0);
                }
            }
        }
    }
    if warm {
        out.warm_solves = inc.as_ref().unwrap().solver_stats().warm_solves;
    }
    out
}

/// Run the fluid Fig. 4 sweep: warm and from-scratch jobs fan out on
/// [`runner::run_parallel`]; trajectories are compared afterwards.
pub fn run_fig4_fluid(seed: u64) -> Fig4FluidResult {
    let mut rng = Pcg32::new(seed, 0xf4f);
    let cluster = Cluster::google_sample(100, &mut rng);
    let total = cluster.total_capacity();
    let jobs: Vec<Job<'_, SimOut>> = vec![
        Box::new(|| simulate(&cluster, WORK_USER1, true)),
        Box::new(|| simulate(&cluster, WORK_USER1, false)),
    ];
    let mut outs = runner::run_parallel(jobs).into_iter();
    let warm = outs.next().expect("warm job");
    let scratch = outs.next().expect("scratch job");

    let mut max_share_err = 0.0f64;
    for (a, b) in warm.share.iter().zip(&scratch.share) {
        for u in 0..3 {
            max_share_err = max_share_err.max((a[u] - b[u]).abs());
        }
    }
    let d = warm.depart.unwrap_or(HORIZON);
    let windows = [
        ("user 1 alone", (50.0, 200.0)),
        ("users 1+2", (250.0, 500.0)),
        ("users 1+2+3", (550.0, (d - 50.0).min(1_000.0))),
        ("after user 1 departs", (d + 50.0, HORIZON)),
    ];
    let phases: Vec<(String, (f64, f64), [f64; 3])> = windows
        .iter()
        .map(|&(label, (lo, hi))| {
            let mut s = [0.0f64; 3];
            let mut cnt = 0usize;
            for (i, row) in warm.share.iter().enumerate() {
                let t = i as f64 * DT;
                if t >= lo && t <= hi {
                    for u in 0..3 {
                        s[u] += row[u];
                    }
                    cnt += 1;
                }
            }
            if cnt > 0 {
                for v in s.iter_mut() {
                    *v /= cnt as f64;
                }
            }
            (label.to_string(), (lo, hi), s)
        })
        .collect();

    Fig4FluidResult {
        share: warm.share,
        tasks: warm.tasks,
        phases,
        depart: warm.depart,
        total_cpu: total[0],
        total_mem: total[1],
        warm_pivots: warm.pivots,
        scratch_pivots: scratch.pivots,
        warm_lp_solves: warm.lp_solves,
        warm_started: warm.warm_solves,
        max_share_err,
    }
}

/// Print the paper-style summary and dump the full time series CSV.
pub fn print(res: &Fig4FluidResult) {
    println!("== Fig. 4 (fluid): dynamic DRFH, 3 users on 100 servers ==");
    println!(
        "pool: {:.2} CPU units, {:.2} memory units (paper: 52.75 / 51.32)",
        res.total_cpu, res.total_mem
    );
    match res.depart {
        Some(t) => println!("user 1 departs at {t:.0} s (paper: 1080 s)"),
        None => println!("user 1 still active at horizon"),
    }
    println!(
        "{:<24} {:>12} {:>8} {:>8} {:>8}",
        "phase", "window", "u1", "u2", "u3"
    );
    for (label, (lo, hi), s) in &res.phases {
        println!(
            "{:<24} [{:>4.0},{:>4.0}] {:>7.1}% {:>7.1}% {:>7.1}%",
            label,
            lo,
            hi,
            s[0] * 100.0,
            s[1] * 100.0,
            s[2] * 100.0
        );
    }
    println!(
        "incremental path: {} LP solves ({} warm), {} search pivots vs \
         {} from-scratch ({:.1}x fewer); trajectories agree to {:.1e}",
        res.warm_lp_solves,
        res.warm_started,
        res.warm_pivots,
        res.scratch_pivots,
        res.scratch_pivots as f64 / res.warm_pivots.max(1) as f64,
        res.max_share_err
    );
    let rows: Vec<String> = res
        .share
        .iter()
        .zip(&res.tasks)
        .enumerate()
        .map(|(i, (s, tk))| {
            format!(
                "{:.1},{:.6},{:.6},{:.6},{:.3},{:.3},{:.3}",
                i as f64 * DT,
                s[0],
                s[1],
                s[2],
                tk[0],
                tk[1],
                tk[2]
            )
        })
        .collect();
    write_csv(
        "fig4_fluid_shares.csv",
        "t,u1_dom,u2_dom,u3_dom,u1_tasks,u2_tasks,u3_tasks",
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fluid_phases_equalize_and_user1_departs() {
        let res = run_fig4_fluid(42);
        // two-user phase: the fluid allocation equalizes exactly
        let p2 = res.phases[1].2;
        assert!(p2[0] > 0.0 && p2[1] > 0.0, "{p2:?}");
        assert!(
            (p2[0] - p2[1]).abs() < 1e-6,
            "two-user fluid shares not equalized: {p2:?}"
        );
        // three-user phase: all present, equalized, below the 2-user level
        let p3 = res.phases[2].2;
        assert!(p3.iter().all(|&s| s > 0.0), "{p3:?}");
        let mx = p3.iter().cloned().fold(0.0, f64::max);
        let mn = p3.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(mx - mn < 1e-6, "three-user fluid shares: {p3:?}");
        assert!(p3[0] < p2[0], "share must drop when user 3 joins");
        // alone phase: user 1 above its fair-shared level
        assert!(res.phases[0].2[0] > p2[0]);
        // departure and rebalance
        let d = res.depart.expect("user 1 must drain its backlog");
        assert!(d > 600.0 && d < 1_800.0, "departure at {d}");
        let p4 = res.phases[3].2;
        assert!(p4[0] < 1e-9, "u1 share must vanish, got {}", p4[0]);
        assert!(p4[1] > p3[1] * 1.1, "u2 {} -> {}", p3[1], p4[1]);
        assert!(p4[2] > p3[2] * 1.1, "u3 {} -> {}", p3[2], p4[2]);
    }

    #[test]
    fn fluid_warm_path_matches_scratch_and_saves_pivots() {
        let res = run_fig4_fluid(42);
        assert!(
            res.max_share_err < 1e-6,
            "warm/scratch trajectories diverged: {:.3e}",
            res.max_share_err
        );
        assert!(
            res.warm_pivots < res.scratch_pivots,
            "warm {} >= scratch {}",
            res.warm_pivots,
            res.scratch_pivots
        );
        assert!(res.warm_started > 0, "no warm solves at all");
    }
}
